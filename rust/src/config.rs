//! Study configuration: one struct describing a full SA run — method,
//! sampler, merging algorithm, execution engine, cluster shape — parsed
//! from CLI-style `key=value` pairs or JSON, consumed by the CLI, the
//! examples and the bench harness.

use crate::adaptive::AdaptiveOptions;
use crate::coordinator::BatchPolicy;
use crate::faults::Faults;
use crate::merging::{FineAlgorithm, TrtmaOptions};
use crate::obs::{Obs, SpanCtx};
use crate::{Error, Result};

/// Which SA method generates the experiments.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SaMethod {
    /// Morris screening with `r` trajectories (sample = r(k+1)).
    Moat { r: usize },
    /// Saltelli VBD with base sample `n` over `k_active` screened
    /// parameters (sample = n(k_active+2)).
    Vbd { n: usize, k_active: usize },
}

impl SaMethod {
    pub fn name(&self) -> &'static str {
        match self {
            SaMethod::Moat { .. } => "moat",
            SaMethod::Vbd { .. } => "vbd",
        }
    }
}

/// Which base sampler draws the design points (Table 4 compares these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    /// Quasi-Monte-Carlo (Halton).
    Qmc,
    /// Plain Monte-Carlo.
    Mc,
    /// Latin Hypercube.
    Lhs,
}

impl SamplerKind {
    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::Qmc => "qmc",
            SamplerKind::Mc => "mc",
            SamplerKind::Lhs => "lhs",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "qmc" | "halton" => Ok(SamplerKind::Qmc),
            "mc" | "monte-carlo" => Ok(SamplerKind::Mc),
            "lhs" | "latin" => Ok(SamplerKind::Lhs),
            other => Err(Error::Config(format!("unknown sampler `{other}`"))),
        }
    }

    /// Instantiate the sampler.
    pub fn build(&self, seed: u64) -> Box<dyn crate::sampling::Sampler> {
        match self {
            SamplerKind::Qmc => Box::new(crate::sampling::HaltonSampler::new(seed)),
            SamplerKind::Mc => Box::new(crate::sampling::MonteCarlo::new(seed)),
            SamplerKind::Lhs => Box::new(crate::sampling::LatinHypercube::new(seed)),
        }
    }
}

/// Execution engine for the planned study.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineMode {
    /// Real PJRT execution of the AOT artifacts.
    Pjrt,
    /// Discrete-event simulation with the cost model.
    Sim,
}

/// Cross-study reuse-cache knobs (see [`crate::cache`]). Disabled by
/// default: the cache changes no results, but callers must opt into the
/// memory/disk footprint.
#[derive(Clone, Debug, PartialEq)]
pub struct CacheSettings {
    /// Master switch.
    pub enabled: bool,
    /// In-memory LRU budget in MiB.
    pub capacity_mb: usize,
    /// Parameter quantization step for cache keys. 0 (the default) means
    /// exact-match reuse: the cache never changes any result. Larger
    /// values trade accuracy for cross-study hit rate — parameter
    /// vectors within the same grid cell share states, and which vector
    /// seeds a cell is first-writer-wins, so quantized results can vary
    /// with scheduling order across runs.
    pub quantize: f64,
    /// Lock shards (concurrency of the shared cache).
    pub shards: usize,
    /// Persistent tier directory (write-through; survives processes).
    pub spill_dir: Option<String>,
}

impl Default for CacheSettings {
    fn default() -> Self {
        Self { enabled: false, capacity_mb: 256, quantize: 0.0, shards: 8, spill_dir: None }
    }
}

impl CacheSettings {
    /// The construction-time [`crate::cache::CacheConfig`] these
    /// settings describe (used by the per-study driver and the
    /// multi-tenant service alike; ignores `enabled`).
    pub fn to_cache_config(&self) -> crate::cache::CacheConfig {
        crate::cache::CacheConfig {
            capacity_bytes: self.capacity_mb * 1024 * 1024,
            shards: self.shards,
            quantize: self.quantize,
            spill_dir: self.spill_dir.as_ref().map(std::path::PathBuf::from),
            faults: Faults::none(),
        }
    }
}

/// The full study configuration.
#[derive(Clone, Debug)]
pub struct StudyConfig {
    pub method: SaMethod,
    pub sampler: SamplerKind,
    pub algorithm: FineAlgorithm,
    /// Coarse (stage-level) merging on/off — off only for the paper's
    /// "No reuse" replica baseline.
    pub coarse: bool,
    pub engine: EngineMode,
    /// Worker count (threads in PJRT mode; simulated WP in sim mode).
    pub workers: usize,
    /// Frontier batch width: how many same-task reuse-tree siblings one
    /// kernel launch carries (PJRT mode). 1 = node-at-a-time execution;
    /// results are bit-identical at every width. Defaults to
    /// [`BatchPolicy::default`]'s width.
    pub batch_width: usize,
    /// Cores per simulated worker node (task-level parallelism inside a
    /// merged stage, paper Fig. 4). 1 = serial stage execution, which is
    /// what the paper's WP-scaling experiments correspond to.
    pub cores: usize,
    /// Tiles per study (each evaluation runs on every tile).
    pub tiles: usize,
    pub seed: u64,
    /// Artifact directory for PJRT mode. The default is the crate's
    /// `artifacts/` directory resolved at *compile time* (so examples,
    /// benches and CI work from any cwd); a relocated release binary
    /// must pass `artifacts=<dir>` explicitly.
    pub artifacts_dir: String,
    /// Optional workflow descriptor file (paper §3.1); defaults to the
    /// built-in paper workflow. Custom workflows simulate with default
    /// task costs; PJRT execution requires matching artifacts.
    pub workflow_file: Option<String>,
    /// Cross-study reuse cache configuration.
    pub cache: CacheSettings,
    /// Fault-injection hook threaded into the worker engines and the
    /// cache's disk tier (see [`crate::faults`]). Inactive by default;
    /// set programmatically (chaos tests, recovery benches) — there is
    /// deliberately no CLI flag, fault plans are code.
    pub faults: Faults,
    /// Run-time adaptive execution (`adaptive=on threshold= min-samples=`;
    /// see [`crate::adaptive`]): execute the design unit-at-a-time and
    /// prune parameters whose CI falls below the threshold. Off by
    /// default — the exhaustive path stays the reference semantics.
    pub adaptive: AdaptiveOptions,
    /// Telemetry handle threaded into the worker engines and the cache
    /// tiers (see [`crate::obs`]). Inactive by default; set
    /// programmatically — like `faults`, there is deliberately no
    /// study-level CLI flag (the serve-level `trace=` / `stats=` flags
    /// activate telemetry and stamp each job's handle here).
    pub obs: Obs,
    /// The span context this study's engine spans parent under —
    /// normally the job's root span, allocated by the serving layer.
    /// `None` leaves the engines span-silent even when `obs` is active
    /// (histograms and counters still record).
    pub trace: Option<SpanCtx>,
}

impl Default for StudyConfig {
    fn default() -> Self {
        Self {
            method: SaMethod::Moat { r: 10 },
            sampler: SamplerKind::Qmc,
            algorithm: FineAlgorithm::Rtma(7),
            coarse: true,
            engine: EngineMode::Pjrt,
            workers: 2,
            batch_width: BatchPolicy::default().width,
            cores: 1,
            tiles: 1,
            seed: 42,
            artifacts_dir: concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into(),
            workflow_file: None,
            cache: CacheSettings::default(),
            faults: Faults::none(),
            adaptive: AdaptiveOptions::default(),
            obs: Obs::none(),
            trace: None,
        }
    }
}

impl StudyConfig {
    /// Parse `key=value` arguments over the defaults. Recognized keys:
    /// `method` (moat|vbd), `r`, `n`, `k-active`, `sampler`
    /// (qmc|mc|lhs), `algo` (none|naive|sca|rtma|trtma), `mbs`,
    /// `max-buckets`, `coarse` (on|off), `engine` (pjrt|sim),
    /// `workers`, `batch-width`, `tiles`, `seed`, `artifacts`, the
    /// reuse-cache knobs `cache` (on|off), `cache-mb`, `cache-quant`,
    /// `cache-shards`, `cache-dir`, and the adaptive-execution knobs
    /// `adaptive` (on|off), `threshold`, `min-samples`.
    pub fn from_args(args: &[String]) -> Result<Self> {
        let mut cfg = StudyConfig::default();
        let mut algo_name = String::from("rtma");
        let mut mbs = 7usize;
        let mut max_buckets = 0usize;
        let mut r = 10usize;
        let mut n = 200usize;
        let mut k_active = 8usize;
        let mut method = String::from("moat");

        for a in args {
            let (key, value) = a
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("expected key=value, got `{a}`")))?;
            let uint = |v: &str| -> Result<usize> {
                v.parse().map_err(|_| Error::Config(format!("`{key}` needs an integer, got `{v}`")))
            };
            let float = |v: &str| -> Result<f64> {
                v.parse().map_err(|_| Error::Config(format!("`{key}` needs a number, got `{v}`")))
            };
            match key {
                "method" => method = value.to_string(),
                "r" => r = uint(value)?,
                "n" => n = uint(value)?,
                "k-active" => k_active = uint(value)?,
                "sampler" => cfg.sampler = SamplerKind::parse(value)?,
                "algo" => algo_name = value.to_string(),
                "mbs" => mbs = uint(value)?,
                "max-buckets" => max_buckets = uint(value)?,
                "coarse" => cfg.coarse = value == "on" || value == "true",
                "engine" => {
                    cfg.engine = match value {
                        "pjrt" => EngineMode::Pjrt,
                        "sim" => EngineMode::Sim,
                        other => {
                            return Err(Error::Config(format!("unknown engine `{other}`")))
                        }
                    }
                }
                "workers" => cfg.workers = uint(value)?.max(1),
                "batch-width" => cfg.batch_width = uint(value)?.max(1),
                "cores" => cfg.cores = uint(value)?.max(1),
                "tiles" => cfg.tiles = uint(value)?.max(1),
                "seed" => cfg.seed = uint(value)? as u64,
                "artifacts" => cfg.artifacts_dir = value.to_string(),
                "workflow" => cfg.workflow_file = Some(value.to_string()),
                "cache" => cfg.cache.enabled = value == "on" || value == "true",
                "cache-mb" => cfg.cache.capacity_mb = uint(value)?,
                "cache-quant" => cfg.cache.quantize = float(value)?.max(0.0),
                "cache-shards" => cfg.cache.shards = uint(value)?.max(1),
                "cache-dir" => cfg.cache.spill_dir = Some(value.to_string()),
                "adaptive" => {
                    cfg.adaptive.enabled = match value {
                        "on" | "true" => true,
                        "off" | "false" => false,
                        v => {
                            return Err(Error::Config(format!(
                                "`adaptive=` wants on|off, got `{v}`"
                            )))
                        }
                    }
                }
                "threshold" => {
                    let t = float(value)?;
                    if t < 0.0 {
                        return Err(Error::Config(format!(
                            "`threshold=` wants a non-negative number, got `{value}`"
                        )));
                    }
                    cfg.adaptive.threshold = t;
                }
                "min-samples" => cfg.adaptive.min_samples = uint(value)?.max(1),
                other => return Err(Error::Config(format!("unknown option `{other}`"))),
            }
        }

        cfg.method = match method.as_str() {
            "moat" => SaMethod::Moat { r },
            "vbd" => SaMethod::Vbd { n, k_active },
            other => return Err(Error::Config(format!("unknown method `{other}`"))),
        };
        cfg.algorithm = parse_algorithm(&algo_name, mbs, max_buckets)?;
        Ok(cfg)
    }

    /// Human-readable one-liner for logs and reports.
    pub fn describe(&self) -> String {
        let cache = if self.cache.enabled {
            format!(
                " cache=on({}MiB,q={}{})",
                self.cache.capacity_mb,
                self.cache.quantize,
                if self.cache.spill_dir.is_some() { ",disk" } else { "" }
            )
        } else {
            String::new()
        };
        let adaptive = if self.adaptive.enabled {
            format!(
                " adaptive=on(thr={},min={})",
                self.adaptive.threshold, self.adaptive.min_samples
            )
        } else {
            String::new()
        };
        format!(
            "{} sampler={} algo={} coarse={} engine={:?} workers={} batch={} tiles={} \
             seed={}{cache}{adaptive}",
            match self.method {
                SaMethod::Moat { r } => format!("moat(r={r})"),
                SaMethod::Vbd { n, k_active } => format!("vbd(n={n},k={k_active})"),
            },
            self.sampler.name(),
            self.algorithm.name(),
            if self.coarse { "on" } else { "off" },
            self.engine,
            self.workers,
            self.batch_width,
            self.tiles,
            self.seed
        )
    }
}

/// Everything the `serve` CLI mode needs, parsed from `key=value`
/// arguments: the service shape, the network endpoints, the per-tenant
/// quota/priority tables, and the residual study options (which become
/// the per-job defaults). See `docs/SERVING.md` for the operator-facing
/// reference of every flag.
#[derive(Clone, Debug, Default)]
pub struct ServeConfig {
    /// `serve-workers=N` — studies executed concurrently.
    pub serve_workers: usize,
    /// `tenant-cap=N` — max in-flight studies per tenant.
    pub tenant_cap: usize,
    /// `tenants=N` — demo mode: number of synthetic tenants.
    pub tenants: usize,
    /// `jobs-per-tenant=M` — demo mode: identical studies per tenant.
    pub jobs_per_tenant: usize,
    /// `jobs=FILE` — one job per line: `tenant=NAME [study options]`.
    pub jobs_file: Option<String>,
    /// `listen=ADDR` — serve the wire protocol on this TCP address
    /// (`127.0.0.1:0` binds an OS-assigned port).
    pub listen: Option<String>,
    /// `addr-file=PATH` — with `listen=`, write the bound address here
    /// once listening (scripts wait on this file).
    pub addr_file: Option<String>,
    /// `submit=ADDR` — client mode: submit `jobs=FILE` to a listening
    /// service instead of running one in-process.
    pub submit: Option<String>,
    /// `drain=on` — client mode: drain the service after collecting the
    /// results and print its bill (the server exits).
    pub drain: bool,
    /// `quota=MB` — default per-tenant memory-tier byte quota.
    pub quota_mb: Option<usize>,
    /// `quota=TENANT:MB` (repeatable) — per-tenant quota overrides.
    pub quota_overrides_mb: Vec<(String, usize)>,
    /// `priority=TENANT:W` (repeatable) — admission weights (default 1).
    pub priorities: Vec<(String, u32)>,
    /// `warm-start=on|off` — pre-admit disk-tier entries at boot.
    /// Unset defaults to on exactly when `cache-dir=` is configured.
    pub warm_start: Option<bool>,
    /// `window=N` — per-connection backpressure: the most submits a
    /// client connection may have unanswered before further submits get
    /// an `over-window` error frame. Unset uses the service default.
    pub submit_window: Option<usize>,
    /// `retries=N` — extra execution attempts a failed job is granted
    /// before its failure is final (0 disables retry). Unset uses the
    /// service default.
    pub job_retries: Option<u32>,
    /// `speculate=on|off` — let idle service workers pre-execute a
    /// tuner's predicted next generation through the single-flight
    /// cache path (warms the cache, never changes a result). Unset
    /// defaults to off; a tune job's own `speculate=on` also enables it
    /// for that job.
    pub speculate: Option<bool>,
    /// `peers=ADDR,ADDR,...` — cluster mode: the full node list
    /// (including this node's own `listen=` address). The 128-bit key
    /// space is consistent-hash partitioned across these nodes and
    /// misses on another node's shard are fetched over the wire.
    pub peers: Vec<String>,
    /// `replicas=N` — cluster mode: hot-prefix replication factor.
    /// Keys this node has served to peers at least twice are pushed to
    /// the next peer on the rendezvous ring, so a dead owner degrades
    /// to replica hits instead of local launches. Unset defaults to 1;
    /// `replicas=0` disables replication.
    pub replicas: Option<usize>,
    /// `route=on|off` — cluster mode: front-door routing. A `submit`
    /// landing on this node is forwarded to the peer owning the
    /// largest share of the study's predicted chain keys, with results
    /// proxied back on the submitting connection. Unset defaults to
    /// off.
    pub route: Option<bool>,
    /// `trace=FILE` — structured telemetry: activate the process-wide
    /// [`crate::obs`] registry and append every span event to FILE as
    /// one JSON line (see `docs/OBSERVABILITY.md`). Server-side only —
    /// rejected in `submit=` client mode, where the spans live on the
    /// serving node.
    pub trace: Option<String>,
    /// `stats=on` — telemetry exposure: the server logs a one-line
    /// metrics digest as jobs complete; a `submit=` client prints a
    /// Prometheus-style text dump of the server's `stats` snapshot
    /// after its jobs finish.
    pub stats: bool,
    /// The residual study options, kept raw for client mode (the server
    /// parses per-job lines itself).
    pub study_args: Vec<String>,
    /// Those options parsed over the default [`StudyConfig`] — the
    /// per-job default study, with the cache force-enabled.
    pub study: StudyConfig,
}

impl ServeConfig {
    /// Parse the `serve` argument list: serve-specific keys are consumed
    /// here, everything else must parse as a study option (the per-job
    /// default). Rejects `cache=off` — the service exists to share one
    /// reuse cache — `listen=` combined with `submit=`, and `peers=`
    /// without a `listen=` address that is a member of the peer list.
    pub fn from_args(args: &[String]) -> Result<Self> {
        let mut sc = ServeConfig {
            serve_workers: 2,
            tenant_cap: 1,
            tenants: 2,
            jobs_per_tenant: 1,
            ..ServeConfig::default()
        };
        for a in args {
            let uint = |v: &str| -> Result<usize> {
                v.parse().map_err(|_| Error::Config(format!("`{a}` needs an integer")))
            };
            match a.split_once('=') {
                Some(("serve-workers", v)) => sc.serve_workers = uint(v)?.max(1),
                Some(("tenant-cap", v)) => sc.tenant_cap = uint(v)?.max(1),
                Some(("tenants", v)) => sc.tenants = uint(v)?.max(1),
                Some(("jobs-per-tenant", v)) => sc.jobs_per_tenant = uint(v)?.max(1),
                Some(("jobs", v)) => sc.jobs_file = Some(v.to_string()),
                Some(("listen", v)) => sc.listen = Some(v.to_string()),
                Some(("addr-file", v)) => sc.addr_file = Some(v.to_string()),
                Some(("submit", v)) => sc.submit = Some(v.to_string()),
                Some(("drain", v)) => sc.drain = v == "on" || v == "true",
                Some(("quota", v)) => {
                    let bad =
                        || Error::Config(format!("`quota=` wants MB or TENANT:MB, got `{v}`"));
                    match v.split_once(':') {
                        Some((tenant, mb)) => sc
                            .quota_overrides_mb
                            .push((tenant.to_string(), mb.parse().map_err(|_| bad())?)),
                        None => sc.quota_mb = Some(v.parse().map_err(|_| bad())?),
                    }
                }
                Some(("priority", v)) => {
                    let bad =
                        || Error::Config(format!("`priority=` wants TENANT:WEIGHT, got `{v}`"));
                    let (tenant, w) = v.split_once(':').ok_or_else(bad)?;
                    let w: usize = w.parse().map_err(|_| bad())?;
                    sc.priorities.push((tenant.to_string(), w.max(1) as u32));
                }
                Some(("peers", v)) => {
                    let bad = || {
                        Error::Config(format!(
                            "`peers=` wants a comma-separated ADDR:PORT list, got `{v}`"
                        ))
                    };
                    let list: Vec<String> =
                        v.split(',').filter(|p| !p.is_empty()).map(str::to_string).collect();
                    if list.is_empty() || list.iter().any(|p| !p.contains(':')) {
                        return Err(bad());
                    }
                    sc.peers = list;
                }
                Some(("warm-start", v)) => sc.warm_start = Some(v == "on" || v == "true"),
                Some(("window", v)) => sc.submit_window = Some(uint(v)?.max(1)),
                Some(("retries", v)) => sc.job_retries = Some(uint(v)? as u32),
                Some(("replicas", v)) => sc.replicas = Some(uint(v)?),
                Some(("route", v)) => {
                    sc.route = Some(match v {
                        "on" | "true" => true,
                        "off" | "false" => false,
                        v => {
                            return Err(Error::Config(format!("`route=` wants on|off, got `{v}`")))
                        }
                    })
                }
                Some(("speculate", v)) => {
                    sc.speculate = Some(match v {
                        "on" | "true" => true,
                        "off" | "false" => false,
                        v => {
                            return Err(Error::Config(format!(
                                "`speculate=` wants on|off, got `{v}`"
                            )))
                        }
                    })
                }
                Some(("trace", v)) => {
                    if v.is_empty() || v == "on" || v == "off" {
                        return Err(Error::Config(format!(
                            "`trace=` wants a span-sink file path, got `{v}`"
                        )));
                    }
                    sc.trace = Some(v.to_string());
                }
                Some(("stats", v)) => {
                    sc.stats = match v {
                        "on" | "true" => true,
                        "off" | "false" => false,
                        v => {
                            return Err(Error::Config(format!("`stats=` wants on|off, got `{v}`")))
                        }
                    }
                }
                _ => sc.study_args.push(a.clone()),
            }
        }
        if sc.listen.is_some() && sc.submit.is_some() {
            return Err(Error::Config(
                "`listen=` (run a service) and `submit=` (be a client) are mutually \
                 exclusive"
                    .into(),
            ));
        }
        // spans are recorded where jobs execute; a client-side sink
        // could only ever be empty, so reject rather than silently
        // write nothing
        if sc.trace.is_some() && sc.submit.is_some() {
            return Err(Error::Config(
                "`trace=` records spans on the serving node; pass it to the `listen=` \
                 side, not a `submit=` client"
                    .into(),
            ));
        }
        if !sc.peers.is_empty() {
            let Some(listen) = &sc.listen else {
                return Err(Error::Config(
                    "`peers=` (cluster mode) needs `listen=ADDR` naming this node".into(),
                ));
            };
            if !sc.peers.iter().any(|p| p == listen) {
                return Err(Error::Config(format!(
                    "`peers=` list must include this node's `listen=` address `{listen}`"
                )));
            }
        }
        // routing and replication shape the cluster fabric; outside
        // cluster mode they could only be silently ignored — reject
        if sc.peers.is_empty() {
            if sc.route == Some(true) {
                return Err(Error::Config(
                    "`route=on` (front-door routing) needs cluster mode (`peers=`)".into(),
                ));
            }
            if sc.replicas.is_some() {
                return Err(Error::Config(
                    "`replicas=` (hot-prefix replication) needs cluster mode (`peers=`)".into(),
                ));
            }
        }
        // the service exists to share one cache across tenants; a
        // cacheless service is a contradiction, so reject rather than
        // silently ignore
        if sc.study_args.iter().any(|a| a == "cache=off" || a == "cache=false") {
            return Err(Error::Config(
                "serve shares one reuse cache across tenants; `cache=off` is not supported \
                 here (tune cache-mb / cache-shards / cache-dir / quota instead)"
                    .into(),
            ));
        }
        sc.study = StudyConfig::from_args(&sc.study_args)?;
        sc.study.cache.enabled = true;
        Ok(sc)
    }

    /// The effective warm-start switch: the explicit flag, defaulting to
    /// on exactly when a persistent tier (`cache-dir=`) is configured.
    pub fn warm_start_effective(&self) -> bool {
        self.warm_start.unwrap_or(self.study.cache.spill_dir.is_some())
    }
}

/// Everything the `tune` CLI mode (and the serve tuning job kind) needs,
/// parsed from `key=value` arguments: the optimizer-loop knobs plus the
/// residual study options (the per-candidate execution environment).
#[derive(Clone, Debug)]
pub struct TuneConfig {
    /// The optimizer-loop knobs (method, budget, population, objective).
    pub options: crate::tune::TuneOptions,
    /// The residual study options, kept raw for the serve client (the
    /// server re-parses per-job argument lists itself).
    pub study_args: Vec<String>,
    /// Those options parsed over the defaults — the per-candidate study
    /// config. The reuse cache defaults ON here (tuning is the
    /// highest-reuse workload); an explicit `cache=off` opts out.
    pub study: StudyConfig,
}

impl TuneConfig {
    /// Parse the `tune` argument list. Tune-specific keys: `tuner`
    /// (nm|simplex|ga|genetic), `budget`, `population`, `k-active`,
    /// `active` (comma-separated parameter names), `objective`
    /// (dice|jaccard), `cost-lambda`, `mutation`, `init` (LO:HI grid
    /// fractions), `speculate` (on|off — ask the serving side to
    /// pre-execute this tuner's predicted next generation). Everything
    /// else must parse as a study option; the study's
    /// `method`/`sampler` are ignored by tuning.
    pub fn from_args(args: &[String]) -> Result<Self> {
        use crate::tune::{ObjectiveKind, TuneOptions, TunerKind};
        let mut opts = TuneOptions::default();
        let mut study_args: Vec<String> = Vec::new();
        for a in args {
            let uint = |v: &str| -> Result<usize> {
                v.parse().map_err(|_| Error::Config(format!("`{a}` needs an integer")))
            };
            let float = |v: &str| -> Result<f64> {
                v.parse().map_err(|_| Error::Config(format!("`{a}` needs a number")))
            };
            match a.split_once('=') {
                Some(("tuner", v)) => opts.method = TunerKind::parse(v)?,
                Some(("budget", v)) => opts.budget = uint(v)?.max(1),
                Some(("population", v)) => opts.population = uint(v)?.max(2),
                Some(("k-active", v)) => {
                    let k = uint(v)?;
                    if !(1..=8).contains(&k) {
                        return Err(Error::Config(format!(
                            "`{a}`: the canonical MOAT screen ranks 8 parameters \
                             (use active=NAMES for a custom set)"
                        )));
                    }
                    opts.k_active = k;
                }
                Some(("active", v)) => {
                    let space = crate::sampling::default_space();
                    let mut active = Vec::new();
                    for name in v.split(',').filter(|n| !n.is_empty()) {
                        let p = space.index_of(name)?;
                        if active.contains(&p) {
                            return Err(Error::Config(format!(
                                "`{a}`: parameter `{name}` listed twice"
                            )));
                        }
                        active.push(p);
                    }
                    if active.is_empty() {
                        return Err(Error::Config("`active=` names no parameters".into()));
                    }
                    opts.active = active;
                }
                Some(("speculate", v)) => {
                    opts.speculate = match v {
                        "on" | "true" => true,
                        "off" | "false" => false,
                        v => {
                            return Err(Error::Config(format!(
                                "`speculate=` wants on|off, got `{v}`"
                            )))
                        }
                    }
                }
                Some(("objective", v)) => opts.objective = ObjectiveKind::parse(v)?,
                Some(("cost-lambda", v)) => opts.cost_lambda = float(v)?.max(0.0),
                Some(("mutation", v)) => opts.mutation = float(v)?.clamp(0.0, 1.0),
                Some(("init", v)) => {
                    let (lo, hi) = v.split_once(':').ok_or_else(|| {
                        Error::Config(format!("`{a}`: expected init=LO:HI fractions"))
                    })?;
                    let (lo, hi) = (float(lo)?, float(hi)?);
                    if !(0.0..=1.0).contains(&lo) || !(0.0..=1.0).contains(&hi) || lo >= hi {
                        return Err(Error::Config(format!(
                            "`{a}`: init window needs 0 <= LO < HI <= 1"
                        )));
                    }
                    opts.init_window = (lo, hi);
                }
                _ => study_args.push(a.clone()),
            }
        }
        let mut study = StudyConfig::from_args(&study_args)?;
        // tuning is the highest-reuse workload: the cache defaults on,
        // and only an explicit cache=off (e.g. for A/B comparisons or
        // the determinism tests) turns it off
        if !study_args.iter().any(|a| a.starts_with("cache=")) {
            study.cache.enabled = true;
        }
        Ok(TuneConfig { options: opts, study_args, study })
    }
}

/// Parse a fine-grain algorithm name plus its size knob.
pub fn parse_algorithm(name: &str, mbs: usize, max_buckets: usize) -> Result<FineAlgorithm> {
    Ok(match name {
        "none" | "stage" | "stage-level" => FineAlgorithm::None,
        "naive" => FineAlgorithm::Naive(mbs),
        "sca" => FineAlgorithm::Sca(mbs),
        "rtma" => FineAlgorithm::Rtma(mbs),
        "trtma" => {
            FineAlgorithm::Trtma(TrtmaOptions::new(if max_buckets > 0 { max_buckets } else { mbs }))
        }
        "trtma-cost" => FineAlgorithm::TrtmaCost(TrtmaOptions::new(if max_buckets > 0 {
            max_buckets
        } else {
            mbs
        })),
        other => return Err(Error::Config(format!("unknown algorithm `{other}`"))),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_are_sane() {
        let c = StudyConfig::default();
        assert_eq!(c.method, SaMethod::Moat { r: 10 });
        assert_eq!(c.sampler, SamplerKind::Qmc);
        assert!(c.coarse);
    }

    #[test]
    fn parses_full_spec() {
        let c = StudyConfig::from_args(&args(&[
            "method=vbd",
            "n=500",
            "k-active=8",
            "sampler=lhs",
            "algo=trtma",
            "max-buckets=24",
            "engine=sim",
            "workers=8",
            "seed=7",
        ]))
        .unwrap();
        assert_eq!(c.method, SaMethod::Vbd { n: 500, k_active: 8 });
        assert_eq!(c.sampler, SamplerKind::Lhs);
        assert!(matches!(c.algorithm, FineAlgorithm::Trtma(o) if o.max_buckets == 24));
        assert_eq!(c.engine, EngineMode::Sim);
        assert_eq!(c.workers, 8);
        assert_eq!(c.seed, 7);
    }

    #[test]
    fn rejects_unknown_keys_and_values() {
        assert!(StudyConfig::from_args(&args(&["bogus=1"])).is_err());
        assert!(StudyConfig::from_args(&args(&["method=sobol"])).is_err());
        assert!(StudyConfig::from_args(&args(&["algo=magic"])).is_err());
        assert!(StudyConfig::from_args(&args(&["workers"])).is_err());
        assert!(StudyConfig::from_args(&args(&["r=xyz"])).is_err());
    }

    #[test]
    fn algorithm_parsing() {
        assert_eq!(parse_algorithm("none", 5, 0).unwrap(), FineAlgorithm::None);
        assert_eq!(parse_algorithm("rtma", 5, 0).unwrap(), FineAlgorithm::Rtma(5));
        assert!(matches!(
            parse_algorithm("trtma", 5, 0).unwrap(),
            FineAlgorithm::Trtma(o) if o.max_buckets == 5
        ));
    }

    #[test]
    fn batch_width_parses_and_clamps() {
        assert_eq!(StudyConfig::default().batch_width, 16);
        let c = StudyConfig::from_args(&args(&["batch-width=4"])).unwrap();
        assert_eq!(c.batch_width, 4);
        assert!(c.describe().contains("batch=4"));
        let c = StudyConfig::from_args(&args(&["batch-width=0"])).unwrap();
        assert_eq!(c.batch_width, 1, "width clamps to >= 1");
        assert!(StudyConfig::from_args(&args(&["batch-width=wide"])).is_err());
    }

    #[test]
    fn cache_defaults_off_and_parses() {
        let c = StudyConfig::default();
        assert!(!c.cache.enabled);
        let c = StudyConfig::from_args(&args(&[
            "cache=on",
            "cache-mb=64",
            "cache-quant=0.5",
            "cache-shards=4",
            "cache-dir=/tmp/rtf-cache",
        ]))
        .unwrap();
        assert!(c.cache.enabled);
        assert_eq!(c.cache.capacity_mb, 64);
        assert_eq!(c.cache.quantize, 0.5);
        assert_eq!(c.cache.shards, 4);
        assert_eq!(c.cache.spill_dir.as_deref(), Some("/tmp/rtf-cache"));
        assert!(c.describe().contains("cache=on"));
        assert!(StudyConfig::from_args(&args(&["cache-quant=abc"])).is_err());
        assert!(StudyConfig::from_args(&args(&["cache-mb=x"])).is_err());
    }

    #[test]
    fn serve_config_parses_all_flags() {
        let sc = ServeConfig::from_args(&args(&[
            "serve-workers=4",
            "tenant-cap=2",
            "listen=127.0.0.1:0",
            "addr-file=/tmp/addr",
            "quota=128",
            "quota=alice:64",
            "priority=alice:4",
            "priority=bob:1",
            "warm-start=on",
            "method=moat",
            "r=2",
            "cache-mb=512",
        ]))
        .unwrap();
        assert_eq!(sc.serve_workers, 4);
        assert_eq!(sc.tenant_cap, 2);
        assert_eq!(sc.listen.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(sc.addr_file.as_deref(), Some("/tmp/addr"));
        assert_eq!(sc.quota_mb, Some(128));
        assert_eq!(sc.quota_overrides_mb, vec![("alice".to_string(), 64)]);
        assert_eq!(sc.priorities, vec![("alice".to_string(), 4), ("bob".to_string(), 1)]);
        assert_eq!(sc.warm_start, Some(true));
        assert!(sc.warm_start_effective());
        assert_eq!(sc.study.method, SaMethod::Moat { r: 2 });
        assert_eq!(sc.study.cache.capacity_mb, 512);
        assert!(sc.study.cache.enabled, "serve force-enables the shared cache");
        assert_eq!(sc.study_args, args(&["method=moat", "r=2", "cache-mb=512"]));
    }

    #[test]
    fn serve_config_defaults_and_warm_start_follow_cache_dir() {
        let sc = ServeConfig::from_args(&[]).unwrap();
        let shape = (sc.serve_workers, sc.tenant_cap, sc.tenants, sc.jobs_per_tenant);
        assert_eq!(shape, (2, 1, 2, 1));
        assert!(!sc.warm_start_effective(), "no disk tier, no warm start");
        let sc = ServeConfig::from_args(&args(&["cache-dir=/tmp/rtf-tier"])).unwrap();
        assert!(sc.warm_start_effective(), "a disk tier warm-starts by default");
        let sc = ServeConfig::from_args(&args(&["cache-dir=/tmp/rtf-tier", "warm-start=off"]))
            .unwrap();
        assert!(!sc.warm_start_effective(), "the explicit flag wins");
    }

    #[test]
    fn serve_config_parses_resilience_flags() {
        let sc = ServeConfig::from_args(&args(&["window=8", "retries=5"])).unwrap();
        assert_eq!(sc.submit_window, Some(8));
        assert_eq!(sc.job_retries, Some(5));
        let sc = ServeConfig::from_args(&[]).unwrap();
        assert_eq!(sc.submit_window, None, "unset defers to the service default");
        assert_eq!(sc.job_retries, None);
        let sc = ServeConfig::from_args(&args(&["window=0", "retries=0"])).unwrap();
        assert_eq!(sc.submit_window, Some(1), "window clamps to >= 1");
        assert_eq!(sc.job_retries, Some(0), "retries=0 legitimately disables retry");
        assert!(ServeConfig::from_args(&args(&["window=wide"])).is_err());
        assert!(ServeConfig::from_args(&args(&["retries=lots"])).is_err());
    }

    #[test]
    fn serve_config_rejects_contradictions() {
        assert!(ServeConfig::from_args(&args(&["cache=off"])).is_err());
        assert!(ServeConfig::from_args(&args(&["listen=a:1", "submit=b:2"])).is_err());
        assert!(ServeConfig::from_args(&args(&["priority=3"])).is_err(), "weight needs a tenant");
        assert!(ServeConfig::from_args(&args(&["quota=alice:x"])).is_err());
        assert!(ServeConfig::from_args(&args(&["bogus=1"])).is_err(), "unknown study key");
    }

    #[test]
    fn serve_config_parses_cluster_flags() {
        let sc = ServeConfig::from_args(&args(&[
            "listen=127.0.0.1:47631",
            "peers=127.0.0.1:47632,127.0.0.1:47631",
        ]))
        .unwrap();
        assert_eq!(sc.peers, args(&["127.0.0.1:47632", "127.0.0.1:47631"]));
        assert_eq!(sc.listen.as_deref(), Some("127.0.0.1:47631"));
        // single-node "cluster" is legal (the remote tier is inert)
        let sc =
            ServeConfig::from_args(&args(&["listen=h:1", "peers=h:1"])).unwrap();
        assert_eq!(sc.peers, args(&["h:1"]));
    }

    #[test]
    fn serve_config_parses_routing_and_replication_flags() {
        let cluster = ["listen=h:1", "peers=h:1,h:2"];
        let sc = ServeConfig::from_args(&args(&cluster)).unwrap();
        assert_eq!(sc.replicas, None, "unset defers to the service default (1)");
        assert_eq!(sc.route, None, "unset defers to the service default (off)");
        let sc = ServeConfig::from_args(&args(&[
            "listen=h:1",
            "peers=h:1,h:2",
            "replicas=2",
            "route=on",
        ]))
        .unwrap();
        assert_eq!(sc.replicas, Some(2));
        assert_eq!(sc.route, Some(true));
        let sc = ServeConfig::from_args(&args(&[
            "listen=h:1",
            "peers=h:1,h:2",
            "replicas=0",
            "route=off",
        ]))
        .unwrap();
        assert_eq!(sc.replicas, Some(0), "replicas=0 disables replication");
        assert_eq!(sc.route, Some(false));
        // both flags shape the cluster fabric: outside cluster mode
        // they'd be silently inert, so they're rejected instead
        let err = ServeConfig::from_args(&args(&["route=on"])).unwrap_err();
        assert!(err.to_string().contains("peers="), "route=on names cluster mode: {err}");
        let err = ServeConfig::from_args(&args(&["replicas=1"])).unwrap_err();
        assert!(err.to_string().contains("peers="), "replicas= names cluster mode: {err}");
        // route=off without a cluster is harmless (scripts share flag
        // sets across single- and multi-node invocations)
        assert!(ServeConfig::from_args(&args(&["route=off"])).is_ok());
    }

    #[test]
    fn serve_config_cluster_needs_listen_in_the_peer_list() {
        let err = ServeConfig::from_args(&args(&["peers=h:1,h:2"])).unwrap_err();
        assert!(err.to_string().contains("listen="), "names the missing flag: {err}");
        let err =
            ServeConfig::from_args(&args(&["listen=h:9", "peers=h:1,h:2"])).unwrap_err();
        assert!(err.to_string().contains("h:9"), "names the absent listen address: {err}");
        assert!(err.to_string().contains("peers="), "names the flag: {err}");
    }

    #[test]
    fn study_config_parses_adaptive_flags() {
        let c = StudyConfig::default();
        assert!(!c.adaptive.enabled, "adaptive defaults off");
        assert_eq!(c.adaptive.threshold, 0.0);
        assert_eq!(c.adaptive.min_samples, 4);
        let c = StudyConfig::from_args(&args(&[
            "adaptive=on",
            "threshold=0.05",
            "min-samples=3",
        ]))
        .unwrap();
        assert!(c.adaptive.enabled);
        assert_eq!(c.adaptive.threshold, 0.05);
        assert_eq!(c.adaptive.min_samples, 3);
        assert!(c.describe().contains("adaptive=on"));
        let c = StudyConfig::from_args(&args(&["adaptive=off"])).unwrap();
        assert!(!c.adaptive.enabled);
        assert!(!c.describe().contains("adaptive"));
        let c = StudyConfig::from_args(&args(&["min-samples=0"])).unwrap();
        assert_eq!(c.adaptive.min_samples, 1, "min-samples clamps to >= 1");
    }

    #[test]
    fn adaptive_parse_errors_name_the_flag_and_value() {
        // PR 6 convention: every malformed form names the flag AND
        // quotes the offending value
        for (bad, flag, value) in [
            ("adaptive=maybe", "adaptive=", "maybe"),
            ("adaptive=1", "adaptive=", "1"),
            ("threshold=tiny", "threshold=", "tiny"),
            ("threshold=-0.5", "threshold=", "-0.5"),
            ("min-samples=few", "min-samples", "few"),
        ] {
            let err = StudyConfig::from_args(&args(&[bad])).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains(flag), "`{bad}` error must name `{flag}`: {msg}");
            assert!(
                msg.contains(&format!("`{value}`")),
                "`{bad}` error must quote the value: {msg}"
            );
        }
    }

    #[test]
    fn serve_config_parses_telemetry_flags() {
        let sc = ServeConfig::from_args(&args(&["trace=/tmp/spans.jsonl", "stats=on"])).unwrap();
        assert_eq!(sc.trace.as_deref(), Some("/tmp/spans.jsonl"));
        assert!(sc.stats);
        let sc = ServeConfig::from_args(&args(&["stats=off"])).unwrap();
        assert!(!sc.stats);
        let sc = ServeConfig::from_args(&[]).unwrap();
        assert_eq!(sc.trace, None, "tracing defaults off");
        assert!(!sc.stats, "stats digest defaults off");
        // `trace=on` is a likely typo for `trace=FILE`: reject it
        // instead of creating a file literally named `on`
        assert!(ServeConfig::from_args(&args(&["trace=on"])).is_err());
        // the sink lives where the jobs run
        let err = ServeConfig::from_args(&args(&["submit=h:1", "trace=/tmp/t"])).unwrap_err();
        assert!(err.to_string().contains("trace="), "names the flag: {err}");
        assert!(err.to_string().contains("submit="), "explains the conflict: {err}");
        // a client may still ask for the stats dump
        let sc = ServeConfig::from_args(&args(&["submit=h:1", "stats=on"])).unwrap();
        assert!(sc.stats);
    }

    #[test]
    fn serve_config_parses_speculate() {
        let sc = ServeConfig::from_args(&args(&["speculate=on"])).unwrap();
        assert_eq!(sc.speculate, Some(true));
        let sc = ServeConfig::from_args(&args(&["speculate=off"])).unwrap();
        assert_eq!(sc.speculate, Some(false));
        let sc = ServeConfig::from_args(&[]).unwrap();
        assert_eq!(sc.speculate, None, "unset defers to the service default");
    }

    #[test]
    fn serve_config_parse_errors_name_the_flag_and_value() {
        // one malformed form per flag; every error names both the flag
        // and the offending value
        for (bad_args, flag, value) in [
            (vec!["quota=lots"], "quota=", "lots"),
            (vec!["quota=alice:many"], "quota=", "alice:many"),
            (vec!["priority=3"], "priority=", "3"),
            (vec!["priority=alice:heavy"], "priority=", "alice:heavy"),
            (vec!["listen=h:1", "peers=h1,h:1"], "peers=", "h1,h:1"),
            (vec!["listen=h:1", "peers="], "peers=", ""),
            (vec!["speculate=sometimes"], "speculate=", "sometimes"),
            (vec!["route=sometimes"], "route=", "sometimes"),
            (vec!["stats=sometimes"], "stats=", "sometimes"),
            (vec!["trace="], "trace=", ""),
            (vec!["adaptive=perhaps"], "adaptive=", "perhaps"),
            (vec!["threshold=-1"], "threshold=", "-1"),
        ] {
            let err = ServeConfig::from_args(&args(&bad_args)).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains(flag), "`{bad_args:?}` error must name `{flag}`: {msg}");
            assert!(
                msg.contains(&format!("`{value}`")),
                "`{bad_args:?}` error must quote the value: {msg}"
            );
        }
    }

    #[test]
    fn tune_config_parses_and_defaults_cache_on() {
        use crate::tune::{ObjectiveKind, TunerKind};
        let tc = TuneConfig::from_args(&args(&[
            "tuner=nm",
            "budget=32",
            "population=6",
            "active=G1,G2",
            "objective=jaccard",
            "cost-lambda=0.01",
            "init=0.5:1.0",
            "seed=9",
            "tiles=2",
        ]))
        .unwrap();
        assert_eq!(tc.options.method, TunerKind::Simplex);
        assert_eq!(tc.options.budget, 32);
        assert_eq!(tc.options.population, 6);
        assert_eq!(tc.options.active, vec![5, 6]);
        assert_eq!(tc.options.objective, ObjectiveKind::Jaccard);
        assert_eq!(tc.options.cost_lambda, 0.01);
        assert_eq!(tc.options.init_window, (0.5, 1.0));
        assert_eq!(tc.study.seed, 9);
        assert_eq!(tc.study.tiles, 2);
        assert!(tc.study.cache.enabled, "tune defaults the cache on");
        assert_eq!(tc.study_args, args(&["seed=9", "tiles=2"]));

        let tc = TuneConfig::from_args(&args(&["cache=off"])).unwrap();
        assert!(!tc.study.cache.enabled, "an explicit cache=off wins");
        assert_eq!(tc.options.active_params().len(), 8, "canonical actives by default");
        let tc = TuneConfig::from_args(&args(&["k-active=3"])).unwrap();
        assert_eq!(tc.options.active_params(), vec![4, 5, 6]);
    }

    #[test]
    fn tune_config_parses_speculate() {
        let tc = TuneConfig::from_args(&args(&["speculate=on"])).unwrap();
        assert!(tc.options.speculate);
        let tc = TuneConfig::from_args(&[]).unwrap();
        assert!(!tc.options.speculate, "speculation defaults off");
        // malformed forms name the flag and quote the value
        for (bad, value) in [("speculate=eager", "eager"), ("speculate=2", "2")] {
            let err = TuneConfig::from_args(&args(&[bad])).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("speculate="), "`{bad}` error names the flag: {msg}");
            assert!(msg.contains(&format!("`{value}`")), "`{bad}` error quotes value: {msg}");
        }
    }

    #[test]
    fn tune_config_rejects_bad_knobs() {
        assert!(TuneConfig::from_args(&args(&["tuner=annealing"])).is_err());
        assert!(TuneConfig::from_args(&args(&["objective=speed"])).is_err());
        assert!(TuneConfig::from_args(&args(&["active=NoSuchParam"])).is_err());
        assert!(TuneConfig::from_args(&args(&["active="])).is_err());
        assert!(TuneConfig::from_args(&args(&["active=G1,G1"])).is_err(), "duplicate dim");
        assert!(TuneConfig::from_args(&args(&["init=0.9:0.1"])).is_err(), "window inverted");
        assert!(TuneConfig::from_args(&args(&["init=0.5"])).is_err(), "missing colon");
        assert!(TuneConfig::from_args(&args(&["k-active=12"])).is_err(), "screen ranks 8");
        assert!(TuneConfig::from_args(&args(&["k-active=0"])).is_err());
        assert!(TuneConfig::from_args(&args(&["budget=x"])).is_err());
        assert!(TuneConfig::from_args(&args(&["bogus=1"])).is_err(), "unknown study key");
    }

    #[test]
    fn samplers_build() {
        for kind in [SamplerKind::Qmc, SamplerKind::Mc, SamplerKind::Lhs] {
            let mut s = kind.build(1);
            let pts = s.draw(4, 3);
            assert_eq!(pts.len(), 4);
            assert!(pts.iter().all(|p| p.len() == 3));
            assert!(pts.iter().flatten().all(|&v| (0.0..1.0).contains(&v)));
        }
    }
}
