//! The RTF manager/worker runtime (paper §2.3) executing study plans on
//! real PJRT engines.
//!
//! The **manager** owns the dependency state of the
//! [`StudyPlan`](crate::merging::StudyPlan) and a
//! FIFO ready queue; **workers** (one OS thread each, with a private
//! [`crate::runtime::PjrtEngine`] — PJRT handles are not `Send`, and one
//! engine per worker is also the faithful topology) request schedule
//! units demand-driven whenever idle, exactly like RTF worker nodes
//! request stage instances. Inter-unit data (region-template states)
//! flows through a reference-counted [`NodeStore`]; states are dropped
//! the moment their last consumer has fetched them, bounding resident
//! memory like the RTF's hierarchical storage layer.
//!
//! Inside a worker, a *merged* unit executes its bucket's reuse tree in
//! frontier order (level-synchronous BFS): every shared task prefix runs
//! **once**, and the same-task siblings of each tree level are stacked
//! into batched kernel launches ([`exec::BatchPolicy`]) — this is where
//! the planned fine-grain reuse turns into actually-skipped (and
//! batch-vectorized) PJRT executions.

mod cluster;
mod exec;
mod store;

pub use cluster::{execute_study, ExecuteOptions, StudyOutcome};
pub use exec::{execute_unit, BatchPolicy, UnitCacheCtx, UnitOutput};
pub use store::NodeStore;
