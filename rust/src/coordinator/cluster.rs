//! The manager/worker cluster: demand-driven unit dispatch over worker
//! threads, each with a private PJRT engine.

use std::collections::{BinaryHeap, HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cache::{fold_keys, node_input_key, reference_fingerprints, tile_fingerprints};
use crate::cache::{CacheStats, Key, ReuseCache, ScopedCounters};
use crate::data::{Plane, TileSet};
use crate::faults::Faults;
use crate::merging::{
    batched_unit_cost, unit_launch_count, CompactGraph, StudyPlan, DEFAULT_LAUNCH_COST_SECS,
    DEFAULT_MARGINAL_COST_SECS,
};
use crate::obs::{Obs, SpanCtx};
use crate::runtime::{ArtifactManifest, PjrtEngine, TaskTimer};
use crate::workflow::StageInstance;
use crate::{Error, Result};

use super::exec::{execute_unit, BatchPolicy, UnitCacheCtx, UnitOutput};
use super::store::{NodeStore, State};

/// Uniquifies spill directories when several studies run concurrently in
/// one process (the pid alone is not enough).
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// Owns one execution's spill directory and removes it — contents and
/// all — when the execution ends, success or failure.
struct SpillDirGuard {
    dir: PathBuf,
}

impl Drop for SpillDirGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

/// Cluster shape and artifact location.
#[derive(Clone, Debug)]
pub struct ExecuteOptions {
    pub workers: usize,
    pub artifacts_dir: PathBuf,
    /// Resident-state ceiling in bytes; states beyond it spill to a
    /// temp directory (the RTF's hierarchical storage layer). `None` =
    /// unbounded.
    pub state_limit_bytes: Option<usize>,
    /// Cross-study reuse cache, shared by every worker engine (and, when
    /// the caller holds it across studies, by successive executions).
    pub cache: Option<Arc<ReuseCache>>,
    /// Per-tenant counter scope this execution accounts its cache
    /// traffic under (multi-tenant serving; see [`crate::serve`]).
    /// `None` leaves only the cache's global counters.
    pub cache_scope: Option<Arc<ScopedCounters>>,
    /// How workers batch reuse-tree frontier siblings into kernel
    /// launches (see [`BatchPolicy`]).
    pub batch: BatchPolicy,
    /// Fault-injection hook installed into every worker engine
    /// (inactive by default; see [`crate::faults`]).
    pub faults: Faults,
    /// Telemetry handle installed into every worker engine (inactive by
    /// default; see [`crate::obs`]).
    pub obs: Obs,
    /// The span every worker engine parents its spans under — normally
    /// the job's root span. `None` keeps engines span-silent even with
    /// `obs` active (histograms only).
    pub obs_span: Option<SpanCtx>,
}

impl ExecuteOptions {
    pub fn new(workers: usize, artifacts_dir: impl Into<PathBuf>) -> Self {
        Self {
            workers: workers.max(1),
            artifacts_dir: artifacts_dir.into(),
            state_limit_bytes: None,
            cache: None,
            cache_scope: None,
            batch: BatchPolicy::default(),
            faults: Faults::none(),
            obs: Obs::none(),
            obs_span: None,
        }
    }

    /// Bound resident inter-unit state, spilling the excess to disk.
    pub fn with_state_limit(mut self, bytes: usize) -> Self {
        self.state_limit_bytes = Some(bytes);
        self
    }

    /// Share a cross-study reuse cache with the worker engines.
    pub fn with_cache(mut self, cache: Arc<ReuseCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Account this execution's cache traffic under a per-tenant scope
    /// (every worker engine mirrors its counted cache operations into
    /// it). The multi-tenant service gives each tenant one scope, so
    /// tenant counters sum exactly to the shared cache's globals.
    pub fn with_cache_scope(mut self, scope: Arc<ScopedCounters>) -> Self {
        self.cache_scope = Some(scope);
        self
    }

    /// Set the frontier batching policy (default: [`BatchPolicy`]'s
    /// width-16; `BatchPolicy::sequential()` restores node-at-a-time
    /// execution).
    pub fn with_batch(mut self, batch: BatchPolicy) -> Self {
        self.batch = batch;
        self
    }

    /// Install a fault-injection hook into every worker engine (see
    /// [`crate::faults`]): scripted launch faults panic a worker
    /// mid-unit, which the dispatch loop converts into a failed study
    /// instead of a wedged one.
    pub fn with_faults(mut self, faults: Faults) -> Self {
        self.faults = faults;
        self
    }

    /// Install a telemetry handle (and the span to parent under) into
    /// every worker engine: launches, lookups and frontier levels record
    /// histograms and — when `span` is set — emit spans of the job's
    /// trace (see [`crate::obs`]).
    pub fn with_obs(mut self, obs: Obs, span: Option<SpanCtx>) -> Self {
        self.obs = obs;
        self.obs_span = span;
        self
    }
}

/// Result of a real (PJRT) study execution.
#[derive(Clone, Debug)]
pub struct StudyOutcome {
    /// Per-evaluation (dice, jaccard, mean-diff) vs. the reference mask.
    pub metrics: Vec<[f32; 3]>,
    /// Per-evaluation scalar output fed to the SA estimators: 1 − dice
    /// (0 = identical to reference, grows with divergence).
    pub y: Vec<f64>,
    /// Wall time of the whole execution (includes engine compilation).
    pub wall: Duration,
    /// Per-task timings merged over all workers (Table 6 source).
    pub timer: TaskTimer,
    /// High-water mark of inter-unit state bytes (memory pressure of the
    /// merge plan — the paper's MaxBucketSize motivation).
    pub peak_state_bytes: usize,
    /// Reuse-cache counters at the end of the execution (when a cache
    /// was attached). Counters accumulate over the cache's lifetime, so
    /// diff successive snapshots for per-study numbers.
    pub cache: Option<CacheStats>,
}

/// Scheduler state shared between the manager and the workers. Ready
/// units are dispatched costliest-first (LPT) by their *batched*
/// execution cost (see [`unit_priority`]), keeping long merged buckets
/// off the straggler tail at low units-per-worker ratios.
struct Sched {
    ready: BinaryHeap<(u64, std::cmp::Reverse<usize>)>,
    indeg: Vec<usize>,
    children: Vec<Vec<usize>>,
    done: usize,
    total: usize,
    failed: Option<String>,
}

/// Execute a planned study on real PJRT engines.
///
/// `tiles` and `references` are keyed by tile id; every evaluation's tile
/// must be present. Returns per-evaluation metrics in evaluation order
/// (`0..n_evals`).
pub fn execute_study(
    opts: &ExecuteOptions,
    plan: &StudyPlan,
    graph: &CompactGraph,
    instances: &[StageInstance],
    tiles: &HashMap<u64, TileSet>,
    references: &HashMap<u64, Plane>,
    n_evals: usize,
) -> Result<StudyOutcome> {
    plan.assert_valid(graph);
    let start = Instant::now();
    let n = plan.units.len();

    // consumers per compact node = distinct downstream units
    let mut consumers = vec![0usize; graph.nodes.len()];
    {
        let mut seen: HashSet<(usize, usize)> = HashSet::new();
        for u in &plan.units {
            for &node in &u.nodes {
                if let Some(p) = graph.nodes[node].parent {
                    if seen.insert((u.id, p)) {
                        consumers[p] += 1;
                    }
                }
            }
        }
    }

    // LPT prices a ready unit by its batched execution cost — launches
    // at the configured width plus marginal per task — not its raw task
    // count: a merged bucket whose reuse tree batches into few launches
    // no longer outranks launch-heavy work of equal task count. Pricing
    // builds one reuse tree per unit at setup (the same trees the
    // planner probe and the executor build again later); folding launch
    // counts into ScheduleUnit at plan time would need the batch width
    // there, which is an execution-time knob
    let priority: Vec<u64> =
        plan.units.iter().map(|u| unit_priority(u, graph, instances, opts.batch.width)).collect();

    let sched = Mutex::new(Sched {
        ready: (0..n)
            .filter(|&i| plan.units[i].deps.is_empty())
            .map(|i| (priority[i], std::cmp::Reverse(i)))
            .collect(),
        indeg: plan.units.iter().map(|u| u.deps.len()).collect(),
        children: {
            let mut ch: Vec<Vec<usize>> = vec![Vec::new(); n];
            for u in &plan.units {
                for &d in &u.deps {
                    ch[d].push(u.id);
                }
            }
            ch
        },
        done: 0,
        total: n,
        failed: None,
    });
    let cv = Condvar::new();
    // spill dirs are per-execution (pid + sequence, so concurrent studies
    // in one process never share) and removed when the guard drops
    let (store, _spill_guard) = match opts.state_limit_bytes {
        Some(limit) => {
            let dir = std::env::temp_dir().join(format!(
                "rtf-reuse-spill-{}-{}",
                std::process::id(),
                SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            std::fs::create_dir_all(&dir)?;
            (NodeStore::with_spill(limit, dir.clone()), Some(SpillDirGuard { dir }))
        }
        None => (NodeStore::new(), None),
    };
    let metrics_map: Mutex<HashMap<usize, [f32; 3]>> = Mutex::new(HashMap::new());
    let timers: Mutex<Vec<(String, f64, u64)>> = Mutex::new(Vec::new());

    // content fingerprints root the cross-study cache keys at the actual
    // pixels (tile ids are study-local and must not leak into keys),
    // folded with the artifact fingerprint so states computed by
    // different kernel versions never alias
    let fps = match &opts.cache {
        Some(_) => {
            let art = Key::from(ArtifactManifest::load(&opts.artifacts_dir)?.fingerprint());
            let mut tile_fps = tile_fingerprints(tiles);
            for fp in tile_fps.values_mut() {
                *fp = fold_keys(art, *fp);
            }
            Some((tile_fps, reference_fingerprints(references)))
        }
        None => None,
    };

    std::thread::scope(|scope| {
        for _ in 0..opts.workers {
            scope.spawn(|| {
                worker_loop(
                    opts, plan, graph, instances, tiles, references, &sched, &cv, &store,
                    &metrics_map, &timers, &consumers, &priority, fps.as_ref(),
                );
            });
        }
    });

    let sched = sched.into_inner().unwrap();
    if let Some(msg) = sched.failed {
        return Err(Error::Coordinator(msg));
    }
    if sched.done != n {
        return Err(Error::Coordinator(format!("only {} of {n} units completed", sched.done)));
    }

    // per-evaluation metrics from the last stage's compact node
    let metrics_map = metrics_map.into_inner().unwrap();
    let mut metrics = Vec::with_capacity(n_evals);
    let mut y = Vec::with_capacity(n_evals);
    for eval in 0..n_evals {
        let nodes = graph
            .eval_nodes
            .get(&eval)
            .ok_or_else(|| Error::Coordinator(format!("evaluation {eval} missing from graph")))?;
        let last = *nodes.last().unwrap();
        let m = metrics_map
            .get(&last)
            .ok_or_else(|| Error::Coordinator(format!("no metrics for eval {eval}")))?;
        metrics.push(*m);
        y.push(1.0 - m[0] as f64);
    }

    let mut timer = TaskTimer::default();
    timer.absorb(&timers.into_inner().unwrap());

    Ok(StudyOutcome {
        metrics,
        y,
        wall: start.elapsed(),
        timer,
        peak_state_bytes: store.peak_bytes(),
        cache: opts.cache.as_ref().map(|c| c.stats()),
    })
}

/// LPT dispatch priority of one unit: its [`batched_unit_cost`] under
/// the execution's frontier batch width (default launch/marginal
/// pricing), in integer microseconds so the ready heap stays `Ord`.
fn unit_priority(
    unit: &crate::merging::ScheduleUnit,
    graph: &CompactGraph,
    instances: &[StageInstance],
    width: usize,
) -> u64 {
    let launches = unit_launch_count(unit, graph, instances, width);
    let cost = batched_unit_cost(
        launches,
        unit.task_cost,
        DEFAULT_LAUNCH_COST_SECS,
        DEFAULT_MARGINAL_COST_SECS,
    );
    (cost * 1e6).round() as u64
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    opts: &ExecuteOptions,
    plan: &StudyPlan,
    graph: &CompactGraph,
    instances: &[StageInstance],
    tiles: &HashMap<u64, TileSet>,
    references: &HashMap<u64, Plane>,
    sched: &Mutex<Sched>,
    cv: &Condvar,
    store: &NodeStore,
    metrics_map: &Mutex<HashMap<usize, [f32; 3]>>,
    timers: &Mutex<Vec<(String, f64, u64)>>,
    consumers: &[usize],
    priority: &[u64],
    fps: Option<&(HashMap<u64, Key>, HashMap<u64, Key>)>,
) {
    let fail = |msg: String| {
        let mut s = sched.lock().unwrap();
        if s.failed.is_none() {
            s.failed = Some(msg);
        }
        cv.notify_all();
    };

    let mut engine = match PjrtEngine::load(&opts.artifacts_dir) {
        Ok(e) => e,
        Err(e) => return fail(format!("worker engine load failed: {e}")),
    };
    if let Some(cache) = &opts.cache {
        engine.set_cache(cache.clone());
        if let Some(scope) = &opts.cache_scope {
            engine.set_cache_scope(scope.clone());
        }
    }
    engine.set_fault_hook(opts.faults.clone());
    engine.set_obs(opts.obs.clone(), opts.obs_span.clone());
    let quantize = opts.cache.as_ref().map(|c| c.quantize_step()).unwrap_or(0.0);

    loop {
        // demand-driven: request the next ready unit
        let unit_id = {
            let mut s = sched.lock().unwrap();
            loop {
                if s.failed.is_some() || s.done == s.total {
                    // flush this worker's timings before leaving
                    timers.lock().unwrap().extend(engine.timer().summary());
                    return;
                }
                if let Some((_, std::cmp::Reverse(u))) = s.ready.pop() {
                    break u;
                }
                s = cv.wait(s).unwrap();
            }
        };
        let unit = &plan.units[unit_id];

        // input state: tile planes for stage 0, upstream node otherwise
        let rep = &instances[graph.nodes[unit.nodes[0]].rep];
        let input: Result<State> = if unit.stage_idx == 0 {
            match tiles.get(&rep.tile) {
                Some(t) => Ok([t.r.clone(), t.g.clone(), t.b.clone()]),
                None => Err(Error::Coordinator(format!("tile {} not provided", rep.tile))),
            }
        } else {
            store.take(graph.nodes[unit.nodes[0]].parent.expect("non-root has parent"))
        };
        let input = match input {
            Ok(i) => i,
            Err(e) => return fail(format!("unit {unit_id}: {e}")),
        };

        let reference = references.get(&rep.tile);
        let cache_ctx = fps.map(|(tile_fps, ref_fps)| UnitCacheCtx {
            base_key: node_input_key(
                graph,
                instances,
                unit.nodes[0],
                tile_fps.get(&rep.tile).copied().unwrap_or(Key::from(0u64)),
                quantize,
            ),
            ref_fp: ref_fps.get(&rep.tile).copied().unwrap_or(Key::from(0u64)),
        });
        // a panicking unit (a backend crash, or a scripted launch fault)
        // must become a *failed study*, not a wedged one: without the
        // catch, the panicking worker dies without ever touching
        // `sched.failed`, and every other worker parks on the condvar
        // forever — `thread::scope` then never joins. Cache claims held
        // by the unit are released during unwinding (RAII
        // [`crate::cache::FlightClaims`]), so waiters on other engines
        // re-claim instead of stalling.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            execute_unit(
                &mut engine,
                unit,
                graph,
                instances,
                input,
                reference,
                cache_ctx,
                opts.batch,
            )
        }))
        .unwrap_or_else(|payload| {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| (*s).to_string()))
                .unwrap_or_else(|| "worker panicked".to_string());
            Err(Error::Coordinator(format!("worker panic: {msg}")))
        });
        match result {
            Ok(UnitOutput::States(states)) => {
                for (node, state) in states {
                    store.put(node, state, consumers[node]);
                }
            }
            Ok(UnitOutput::Metrics(ms)) => {
                metrics_map.lock().unwrap().extend(ms);
            }
            Err(e) => return fail(format!("unit {unit_id} failed: {e}")),
        }

        // completion: release dependents
        {
            let mut s = sched.lock().unwrap();
            s.done += 1;
            let children = std::mem::take(&mut s.children[unit_id]);
            for c in children {
                s.indeg[c] -= 1;
                if s.indeg[c] == 0 {
                    s.ready.push((priority[c], std::cmp::Reverse(c)));
                }
            }
            cv.notify_all();
        }
    }
}
