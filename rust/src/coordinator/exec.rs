//! Execution of one schedule unit on one worker engine: the bucket's
//! reuse tree runs depth-first so shared task prefixes execute once.
//!
//! With a cross-study cache attached to the engine, every tree task node
//! carries a content-addressed chain key (unit input key folded through
//! the quantized task signatures along the path); task nodes whose key
//! hits the cache short-circuit — their subtree continues from the cached
//! state without touching PJRT — and misses publish what they compute.

use crate::cache::{chain_key, task_cache_sig};
use crate::data::Plane;
use crate::merging::reuse_tree::ReuseTree;
use crate::merging::{CompactGraph, MergeStage, ScheduleUnit};
use crate::runtime::PjrtEngine;
use crate::workflow::StageInstance;
use crate::{Error, Result};

use super::store::State;

/// What a unit produced: chain stages output 3-plane states per compact
/// node; the comparison stage outputs (dice, jaccard, diff) per node.
pub enum UnitOutput {
    States(Vec<(usize, State)>),
    Metrics(Vec<(usize, [f32; 3])>),
}

/// Cache context for one unit: the content key of the unit's input state
/// and the fingerprint of the tile's reference mask (for metric keys).
#[derive(Clone, Copy, Debug)]
pub struct UnitCacheCtx {
    pub base_key: u64,
    pub ref_fp: u64,
}

/// Everything the depth-first walk needs besides the engine and the
/// per-node state.
struct DfsCtx<'a> {
    tree: &'a ReuseTree,
    unit: &'a ScheduleUnit,
    graph: &'a CompactGraph,
    instances: &'a [StageInstance],
    quantize: f64,
}

/// Execute `unit` given its input state. For the comparison stage a
/// reference mask must be supplied. `cache_ctx` enables cross-study
/// memoization (requires a cache attached to the engine).
pub fn execute_unit(
    engine: &mut PjrtEngine,
    unit: &ScheduleUnit,
    graph: &CompactGraph,
    instances: &[StageInstance],
    input: State,
    reference: Option<&Plane>,
    cache_ctx: Option<UnitCacheCtx>,
) -> Result<UnitOutput> {
    let rep = &instances[graph.nodes[unit.nodes[0]].rep];
    let quantize = engine.cache().map(|c| c.quantize_step()).unwrap_or(0.0);
    let keyed = engine.cache().is_some();
    let compare = rep.tasks.len() == 1 && rep.tasks[0].name == engine.manifest().compare_task;
    if compare {
        let reference = reference.ok_or_else(|| {
            Error::Coordinator(format!("unit {} (comparison) needs a reference mask", unit.id))
        })?;
        let key = match cache_ctx {
            Some(ctx) if keyed => Some(chain_key(
                chain_key(ctx.base_key, task_cache_sig(&rep.tasks[0], quantize)),
                ctx.ref_fp,
            )),
            _ => None,
        };
        // all nodes of the unit share the input: one PJRT execution
        let (m, _hit) = engine.execute_compare_keyed(key, &input, reference)?;
        return Ok(UnitOutput::Metrics(unit.nodes.iter().map(|&n| (n, m)).collect()));
    }

    // Build the bucket's reuse tree; member i of the tree is unit.nodes[i].
    let stages: Vec<MergeStage> = unit
        .nodes
        .iter()
        .enumerate()
        .map(|(i, &n)| MergeStage::new(i, instances[graph.nodes[n].rep].task_path()))
        .collect();
    let tree = ReuseTree::build(&stages);
    let mut out: Vec<(usize, State)> = Vec::with_capacity(unit.nodes.len());
    // state stays literal-resident along the chain; planes materialize
    // only at the leaves (unit boundaries) — EXPERIMENTS.md §Perf
    let lit_input = engine.lit_state(&input)?;
    let base_key = match cache_ctx {
        Some(ctx) if keyed => Some(ctx.base_key),
        _ => None,
    };
    let cx = DfsCtx { tree: &tree, unit, graph, instances, quantize };
    dfs(engine, &cx, tree.root, lit_input, base_key, &mut out)?;
    if out.len() != unit.nodes.len() {
        return Err(Error::Coordinator(format!(
            "unit {} produced {} states for {} nodes",
            unit.id,
            out.len(),
            unit.nodes.len()
        )));
    }
    Ok(UnitOutput::States(out))
}

/// Depth-first execution: every tree task node runs once (or is served by
/// the cache); states are cloned only at fan-out points (a node with c
/// children clones c−1 times), which is the minimum for by-value
/// branching.
///
/// The planning-time probe `merging/study.rs::count_cached` mirrors this
/// walk (same tree, same level→task resolution, same key chaining) —
/// keep the two in sync.
fn dfs(
    engine: &mut PjrtEngine,
    cx: &DfsCtx,
    node: usize,
    state: [xla::Literal; 3],
    key: Option<u64>,
    out: &mut Vec<(usize, State)>,
) -> Result<()> {
    for &c in &cx.tree.nodes[node].children {
        if let Some(member) = cx.tree.nodes[c].stage {
            // leaf: materialize this member's final state as planes
            out.push((cx.unit.nodes[member], engine.plane_state(&state)?));
            continue;
        }
        let level = cx.tree.nodes[c].level; // 1-based task level
        let member = first_member(cx.tree, c);
        let node_id = cx.unit.nodes[member];
        let task = &cx.instances[cx.graph.nodes[node_id].rep].tasks[level - 1];
        let params: Vec<f32> = task.params.iter().map(|&v| v as f32).collect();
        let child_key = key.map(|k| chain_key(k, task_cache_sig(task, cx.quantize)));
        let (next, _hit) =
            engine.execute_task_lit_keyed(&task.name, child_key, &state, &params)?;
        dfs(engine, cx, c, next, child_key, out)?;
    }
    Ok(())
}

/// Any member (stage index into the unit) whose leaf lies under `node`.
fn first_member(tree: &ReuseTree, node: usize) -> usize {
    let mut v = node;
    loop {
        if let Some(s) = tree.nodes[v].stage {
            return s;
        }
        v = tree.nodes[v].children[0];
    }
}
