//! Execution of one schedule unit on one worker engine: the bucket's
//! reuse tree runs depth-first so shared task prefixes execute once.

use crate::data::Plane;
use crate::merging::reuse_tree::ReuseTree;
use crate::merging::{CompactGraph, MergeStage, ScheduleUnit};
use crate::runtime::PjrtEngine;
use crate::workflow::StageInstance;
use crate::{Error, Result};

use super::store::State;

/// What a unit produced: chain stages output 3-plane states per compact
/// node; the comparison stage outputs (dice, jaccard, diff) per node.
pub enum UnitOutput {
    States(Vec<(usize, State)>),
    Metrics(Vec<(usize, [f32; 3])>),
}

/// Execute `unit` given its input state. For the comparison stage a
/// reference mask must be supplied.
pub fn execute_unit(
    engine: &mut PjrtEngine,
    unit: &ScheduleUnit,
    graph: &CompactGraph,
    instances: &[StageInstance],
    input: State,
    reference: Option<&Plane>,
) -> Result<UnitOutput> {
    let rep = &instances[graph.nodes[unit.nodes[0]].rep];
    let compare = rep.tasks.len() == 1 && rep.tasks[0].name == engine.manifest().compare_task;
    if compare {
        let reference = reference.ok_or_else(|| {
            Error::Coordinator(format!("unit {} (comparison) needs a reference mask", unit.id))
        })?;
        // all nodes of the unit share the input: one PJRT execution
        let m = engine.execute_compare(&input, reference)?;
        return Ok(UnitOutput::Metrics(unit.nodes.iter().map(|&n| (n, m)).collect()));
    }

    // Build the bucket's reuse tree; member i of the tree is unit.nodes[i].
    let stages: Vec<MergeStage> = unit
        .nodes
        .iter()
        .enumerate()
        .map(|(i, &n)| MergeStage::new(i, instances[graph.nodes[n].rep].task_path()))
        .collect();
    let tree = ReuseTree::build(&stages);
    let mut out: Vec<(usize, State)> = Vec::with_capacity(unit.nodes.len());
    // state stays literal-resident along the chain; planes materialize
    // only at the leaves (unit boundaries) — EXPERIMENTS.md §Perf
    let lit_input = engine.lit_state(&input)?;
    dfs(engine, &tree, tree.root, lit_input, unit, graph, instances, &mut out)?;
    if out.len() != unit.nodes.len() {
        return Err(Error::Coordinator(format!(
            "unit {} produced {} states for {} nodes",
            unit.id,
            out.len(),
            unit.nodes.len()
        )));
    }
    Ok(UnitOutput::States(out))
}

/// Depth-first execution: every tree task node runs once; states are
/// cloned only at fan-out points (a node with c children clones c−1
/// times), which is the minimum for by-value branching.
#[allow(clippy::too_many_arguments)]
fn dfs(
    engine: &mut PjrtEngine,
    tree: &ReuseTree,
    node: usize,
    state: [xla::Literal; 3],
    unit: &ScheduleUnit,
    graph: &CompactGraph,
    instances: &[StageInstance],
    out: &mut Vec<(usize, State)>,
) -> Result<()> {
    let children = &tree.nodes[node].children;
    for (i, &c) in children.iter().enumerate() {
        let last = i + 1 == children.len();
        if let Some(member) = tree.nodes[c].stage {
            // leaf: materialize this member's final state as planes
            out.push((unit.nodes[member], engine.plane_state(&state)?));
            continue;
        }
        let level = tree.nodes[c].level; // 1-based task level
        let member = first_member(tree, c);
        let task = &instances[graph.nodes[unit.nodes[member]].rep].tasks[level - 1];
        let params: Vec<f32> = task.params.iter().map(|&v| v as f32).collect();
        let next = engine.execute_task_lit(&task.name, &state, &params)?;
        dfs(engine, tree, c, next, unit, graph, instances, out)?;
        let _ = last;
    }
    Ok(())
}

/// Any member (stage index into the unit) whose leaf lies under `node`.
fn first_member(tree: &ReuseTree, node: usize) -> usize {
    let mut v = node;
    loop {
        if let Some(s) = tree.nodes[v].stage {
            return s;
        }
        v = tree.nodes[v].children[0];
    }
}
