//! Execution of one schedule unit on one worker engine: the bucket's
//! reuse tree runs in *frontier order* (level-synchronous BFS), so the
//! sibling evaluations that fan out below a shared task prefix — the
//! dominant shape in Morris/VBD studies — execute in a handful of
//! batched kernel launches per tree level instead of one launch per
//! node.
//!
//! With a cross-study cache attached to the engine, every tree task node
//! carries a content-addressed chain key (unit input key folded through
//! the quantized task signatures along the path, computed over the same
//! [`ReuseTree::chain_keys`] walk the planner probes); each batched
//! launch partitions its lanes into cache hits (served as refcount bumps
//! on the stored states) and misses (executed in one call, published on
//! completion).
//!
//! Memory note: the frontier holds one literal state per live tree node
//! of two adjacent levels (a level's inputs and outputs), where the old
//! depth-first walk held one state per level along a root-to-leaf path.
//! At study tile sizes this is a few MiB per worker; the policy width
//! caps how many *outputs* a single launch materializes at once.

use std::sync::Arc;
use std::time::Instant;

use crate::cache::{metrics_key, task_cache_sig, Key};
use crate::data::Plane;
use crate::merging::reuse_tree::{ReuseTree, WalkNode};
use crate::merging::{unit_stages, CompactGraph, ScheduleUnit};
use crate::obs::{span, ObsInner, SpanCtx};
use crate::runtime::{PjrtEngine, TaskId};
use crate::workflow::{StageInstance, TaskInstance};
use crate::{Error, Result};

use super::store::State;

/// How the executor groups reuse-tree frontier nodes into kernel
/// launches. `width == 1` is the node-at-a-time baseline (one backend
/// call per tree node — the cost profile of the old depth-first walk);
/// wider policies stack up to `width` same-task siblings into a single
/// batched call with the per-pixel loops vectorized across the batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Maximum evaluations per kernel launch (≥ 1).
    pub width: usize,
}

impl BatchPolicy {
    pub fn new(width: usize) -> Self {
        Self { width: width.max(1) }
    }

    /// The node-at-a-time (unbatched) policy.
    pub fn sequential() -> Self {
        Self { width: 1 }
    }
}

impl Default for BatchPolicy {
    /// Width 16: fills 8-lane f32 SIMD twice per pixel step while
    /// keeping a launch's output working set (16 × 3 planes) modest.
    fn default() -> Self {
        Self { width: 16 }
    }
}

/// What a unit produced: chain stages output 3-plane states per compact
/// node; the comparison stage outputs (dice, jaccard, diff) per node.
pub enum UnitOutput {
    States(Vec<(usize, State)>),
    Metrics(Vec<(usize, [f32; 3])>),
}

/// Cache context for one unit: the content key of the unit's input state
/// and the fingerprint of the tile's reference mask (for metric keys).
#[derive(Clone, Copy, Debug)]
pub struct UnitCacheCtx {
    pub base_key: Key,
    pub ref_fp: Key,
}

/// Everything the frontier walk needs besides the engine and the
/// per-node states.
struct FrontierCtx<'a> {
    tree: &'a ReuseTree,
    unit: &'a ScheduleUnit,
    graph: &'a CompactGraph,
    instances: &'a [StageInstance],
}

impl<'a> FrontierCtx<'a> {
    /// The task a tree node at 1-based `level` runs, resolved through
    /// any member whose leaf lies under it (all members below share the
    /// task prefix). This resolution is what [`ReuseTree::chain_keys`]
    /// receives on both the planning and the execution side.
    fn task_of(&self, level: usize, member: usize) -> &'a TaskInstance {
        let node_id = self.unit.nodes[member];
        &self.instances[self.graph.nodes[node_id].rep].tasks[level - 1]
    }
}

/// Execute `unit` given its input state. For the comparison stage a
/// reference mask must be supplied. `cache_ctx` enables cross-study
/// memoization (requires a cache attached to the engine); `batch`
/// bounds how many frontier siblings share one kernel launch.
#[allow(clippy::too_many_arguments)]
pub fn execute_unit(
    engine: &mut PjrtEngine,
    unit: &ScheduleUnit,
    graph: &CompactGraph,
    instances: &[StageInstance],
    input: State,
    reference: Option<&Plane>,
    cache_ctx: Option<UnitCacheCtx>,
    batch: BatchPolicy,
) -> Result<UnitOutput> {
    let rep = &instances[graph.nodes[unit.nodes[0]].rep];
    let quantize = engine.cache().map(|c| c.quantize_step()).unwrap_or(0.0);
    let keyed = engine.cache().is_some();
    let compare = rep.tasks.len() == 1 && rep.tasks[0].name == engine.manifest().compare_task;
    if compare {
        let reference = reference.ok_or_else(|| {
            Error::Coordinator(format!("unit {} (comparison) needs a reference mask", unit.id))
        })?;
        let key = match cache_ctx {
            Some(ctx) if keyed => Some(metrics_key(
                ctx.base_key,
                task_cache_sig(&rep.tasks[0], quantize),
                ctx.ref_fp,
            )),
            _ => None,
        };
        // all nodes of the unit share the input: one PJRT execution
        let (m, _hit) = engine.execute_compare_keyed(key, &input, reference)?;
        return Ok(UnitOutput::Metrics(unit.nodes.iter().map(|&n| (n, m)).collect()));
    }

    // Build the bucket's reuse tree from the same merge input the
    // planner probes; member i of the tree is unit.nodes[i].
    let tree = ReuseTree::build(&unit_stages(unit, graph, instances));
    let mut out: Vec<(usize, State)> = Vec::with_capacity(unit.nodes.len());
    // state stays literal-resident along the chain; planes materialize
    // only at the leaves (unit boundaries) — EXPERIMENTS.md §Perf
    let lit_input = engine.lit_state(&input)?;
    let cx = FrontierCtx { tree: &tree, unit, graph, instances };
    let levels = tree.walk();
    // per-node content chain keys, over the same walk the planner probes
    let keys: Option<Vec<Key>> = match cache_ctx {
        Some(ctx) if keyed => Some(
            tree.chain_keys(&levels, ctx.base_key, |level, member| {
                task_cache_sig(cx.task_of(level, member), quantize)
            }),
        ),
        _ => None,
    };
    frontier(engine, &cx, &levels, lit_input, keys.as_deref(), batch, &mut out)?;
    if out.len() != unit.nodes.len() {
        return Err(Error::Coordinator(format!(
            "unit {} produced {} states for {} nodes",
            unit.id,
            out.len(),
            unit.nodes.len()
        )));
    }
    Ok(UnitOutput::States(out))
}

/// Level-synchronous execution over [`ReuseTree::walk`]: each level's
/// task nodes — all instantiations of the *same* task, by construction
/// of the merge groups — run in `ceil(n / width)` batched launches;
/// stage leaves materialize their parent's state as the member's output.
/// Every tree task node still executes exactly once (or is served by the
/// cache); a level's input states are dropped as soon as the level
/// completes.
fn frontier(
    engine: &mut PjrtEngine,
    cx: &FrontierCtx,
    levels: &[Vec<WalkNode>],
    input: [xla::Literal; 3],
    keys: Option<&[Key]>,
    batch: BatchPolicy,
    out: &mut Vec<(usize, State)>,
) -> Result<()> {
    let tree = cx.tree;
    let mut states: Vec<Option<[xla::Literal; 3]>> = vec![None; tree.nodes.len()];
    states[tree.root] = Some(input);
    // With tracing on, each level gets a span and the launches / lookups
    // inside it re-parent under that span; `traced` captures the job ctx
    // up front so the per-level cost is two Arc clones when active, zero
    // branches extra when off.
    let traced: Option<(Arc<ObsInner>, SpanCtx)> = {
        let (obs, sc) = engine.obs_ctx();
        match (obs.get(), sc) {
            (Some(o), Some(sc)) => Some((Arc::clone(o), sc.clone())),
            _ => None,
        }
    };
    for (li, level) in levels.iter().enumerate() {
        let lvl = traced.as_ref().map(|(o, sc)| {
            let span_id = o.next_span();
            let prev = engine.swap_obs_span(Some(sc.child(span_id)));
            (span_id, Instant::now(), prev)
        });
        let mut pending: Vec<WalkNode> = Vec::with_capacity(level.len());
        for n in level {
            match n.stage {
                Some(member) => {
                    let parent = states[n.parent].as_ref().ok_or_else(|| {
                        Error::Coordinator(format!(
                            "unit {}: state of leaf parent {} missing",
                            cx.unit.id, n.parent
                        ))
                    })?;
                    out.push((cx.unit.nodes[member], engine.plane_state(parent)?));
                }
                None => pending.push(*n),
            }
        }
        if !pending.is_empty() {
            let id = engine.require_id(&cx.task_of(pending[0].level, pending[0].member).name)?;
            for chunk in pending.chunks(batch.width.max(1)) {
                run_chunk(engine, cx, id, chunk, keys, &mut states)?;
            }
        }
        // this level consumed its parents' states: free them
        for n in level {
            states[n.parent] = None;
        }
        // restore the job span and close the level (error paths skip
        // this; the service re-arms the engine's span per job, so a
        // failed job can't leak a stale level parent into the next one)
        if let Some((span_id, started, prev)) = lvl {
            engine.swap_obs_span(prev);
            let (o, sc) = traced.as_ref().expect("lvl implies traced");
            let dur = started.elapsed();
            o.emit_timed(sc, span::LEVEL, span_id, started, dur, format!("level {li} nodes={}", level.len()));
        }
    }
    Ok(())
}

/// Execute one frontier chunk: a single batched keyed call for `B > 1`,
/// the scalar keyed path for singleton chunks (which makes `width == 1`
/// exactly the node-at-a-time baseline).
fn run_chunk(
    engine: &mut PjrtEngine,
    cx: &FrontierCtx,
    id: TaskId,
    chunk: &[WalkNode],
    keys: Option<&[Key]>,
    states: &mut [Option<[xla::Literal; 3]>],
) -> Result<()> {
    let params: Vec<Vec<f32>> = chunk
        .iter()
        .map(|n| cx.task_of(n.level, n.member).params.iter().map(|&v| v as f32).collect())
        .collect();
    let node_keys: Vec<Option<Key>> = chunk.iter().map(|n| keys.map(|k| k[n.node])).collect();
    let missing = |n: &WalkNode| {
        Error::Coordinator(format!("unit {}: state of parent {} missing", cx.unit.id, n.parent))
    };
    if chunk.len() == 1 {
        let n = &chunk[0];
        let parent = states[n.parent].as_ref().ok_or_else(|| missing(n))?;
        let (st, _hit) = engine.execute_task_lit_keyed_id(id, node_keys[0], parent, &params[0])?;
        states[n.node] = Some(st);
        return Ok(());
    }
    let results = {
        let mut parent_refs: Vec<&[xla::Literal; 3]> = Vec::with_capacity(chunk.len());
        for n in chunk {
            parent_refs.push(states[n.parent].as_ref().ok_or_else(|| missing(n))?);
        }
        let p_refs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
        engine.execute_task_batch_keyed(id, &node_keys, &parent_refs, &p_refs)?
    };
    for (n, (st, _hit)) in chunk.iter().zip(results) {
        states[n.node] = Some(st);
    }
    Ok(())
}
