//! Reference-counted inter-unit state store — the region-template data
//! plane of the coordinator, with an optional memory bound.
//!
//! The paper limits `MaxBucketSize` partly because merged-stage
//! intermediate state must fit in node memory (§3.3). The store makes
//! that pressure first-class: states are held as [`DataRegion`]s, and
//! when resident bytes exceed the configured limit the oldest states
//! spill to disk (the RTF's hierarchical storage layer) and transparently
//! reload on consumption.

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::Mutex;

use crate::data::{DataRegion, Plane};
use crate::{Error, Result};

/// The 3-plane chain state a stage outputs.
pub type State = [Plane; 3];

struct Entry {
    regions: Vec<DataRegion>,
    /// Units still needing this node's output.
    consumers: usize,
}

impl Entry {
    fn resident_bytes(&self) -> usize {
        self.regions.iter().map(DataRegion::resident_bytes).sum()
    }
}

struct Inner {
    map: HashMap<usize, Entry>,
    /// Node ids in insertion order — spill victims are taken oldest-first.
    order: VecDeque<usize>,
    peak_bytes: usize,
    spills: usize,
}

/// Thread-safe store of compact-node outputs with consumer counting:
/// a `take` by the last consumer removes the entry (memory bound =
/// frontier of the compact graph, not the whole study). With a spill
/// configuration, resident bytes never exceed the limit (modulo the
/// entry currently being inserted).
pub struct NodeStore {
    inner: Mutex<Inner>,
    /// Resident-byte ceiling; `usize::MAX` = unbounded.
    limit: usize,
    spill_dir: Option<PathBuf>,
}

impl NodeStore {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                order: VecDeque::new(),
                peak_bytes: 0,
                spills: 0,
            }),
            limit: usize::MAX,
            spill_dir: None,
        }
    }

    /// A store that spills to `dir` once resident state exceeds
    /// `limit_bytes`.
    pub fn with_spill(limit_bytes: usize, dir: impl Into<PathBuf>) -> Self {
        let mut s = Self::new();
        s.limit = limit_bytes;
        s.spill_dir = Some(dir.into());
        s
    }

    /// Publish `node`'s output for `consumers` downstream units. With
    /// zero consumers the state is dropped immediately.
    pub fn put(&self, node: usize, state: State, consumers: usize) {
        if consumers == 0 {
            return;
        }
        let regions: Vec<DataRegion> = state
            .into_iter()
            .enumerate()
            .map(|(i, p)| DataRegion::in_memory(format!("node{node}.plane{i}"), node as u64, p))
            .collect();
        let mut m = self.inner.lock().unwrap();
        m.map.insert(node, Entry { regions, consumers });
        m.order.push_back(node);
        let resident: usize = m.map.values().map(Entry::resident_bytes).sum();
        m.peak_bytes = m.peak_bytes.max(resident);
        if let Some(dir) = &self.spill_dir {
            let mut resident = resident;
            // spill oldest entries (not the one just inserted) to honor
            // the limit; ignore spill I/O errors only by keeping resident
            let victims: Vec<usize> = m.order.iter().copied().filter(|&v| v != node).collect();
            for v in victims {
                if resident <= self.limit {
                    break;
                }
                if let Some(e) = m.map.get_mut(&v) {
                    let before = e.resident_bytes();
                    if before == 0 {
                        continue; // already spilled
                    }
                    let mut ok = true;
                    for r in &mut e.regions {
                        if r.spill(dir).is_err() {
                            ok = false;
                        }
                    }
                    if ok {
                        resident -= before;
                        m.spills += 1;
                    }
                }
            }
        }
    }

    /// Fetch `node`'s output for one consumer: clones unless this is the
    /// last consumer, in which case the entry is removed and moved out.
    /// Spilled states reload transparently.
    pub fn take(&self, node: usize) -> Result<State> {
        let mut m = self.inner.lock().unwrap();
        let e = m
            .map
            .get_mut(&node)
            .ok_or_else(|| Error::Coordinator(format!("state of node {node} not available")))?;
        e.consumers -= 1;
        let last = e.consumers == 0;
        let mut planes = Vec::with_capacity(3);
        for r in &mut e.regions {
            planes.push(r.fetch()?.clone());
        }
        if last {
            m.map.remove(&node);
            m.order.retain(|&v| v != node);
        }
        let mut it = planes.into_iter();
        Ok([it.next().unwrap(), it.next().unwrap(), it.next().unwrap()])
    }

    /// Entries currently resident (in memory or spilled).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// High-water mark of resident plane bytes.
    pub fn peak_bytes(&self) -> usize {
        self.inner.lock().unwrap().peak_bytes
    }

    /// Entries spilled to disk so far.
    pub fn spill_count(&self) -> usize {
        self.inner.lock().unwrap().spills
    }
}

impl Default for NodeStore {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(v: f32) -> State {
        [Plane::filled(v, 2, 2), Plane::filled(v, 2, 2), Plane::filled(v, 2, 2)]
    }

    #[test]
    fn last_take_removes_entry() {
        let s = NodeStore::new();
        s.put(1, state(1.0), 2);
        assert_eq!(s.len(), 1);
        let a = s.take(1).unwrap();
        assert_eq!(a[0].get(0, 0), 1.0);
        assert_eq!(s.len(), 1, "one consumer left");
        let _ = s.take(1).unwrap();
        assert!(s.is_empty(), "last consumer drops the entry");
        assert!(s.take(1).is_err());
    }

    #[test]
    fn zero_consumers_never_stored() {
        let s = NodeStore::new();
        s.put(5, state(2.0), 0);
        assert!(s.is_empty());
    }

    #[test]
    fn peak_bytes_tracks_high_water() {
        let s = NodeStore::new();
        s.put(1, state(1.0), 1);
        s.put(2, state(2.0), 1);
        let two = s.peak_bytes();
        let _ = s.take(1).unwrap();
        let _ = s.take(2).unwrap();
        assert!(s.is_empty());
        assert_eq!(s.peak_bytes(), two, "peak survives drains");
        assert_eq!(two, 2 * 3 * 4 * 4); // 2 nodes x 3 planes x 4 px x 4 B
    }

    #[test]
    fn missing_node_is_coordinator_error() {
        let s = NodeStore::new();
        assert!(matches!(s.take(9), Err(Error::Coordinator(_))));
    }

    #[test]
    fn spill_and_reload_round_trip() {
        let dir = std::env::temp_dir().join(format!("rtf-store-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // each state = 48 bytes; limit of 60 forces spilling after 2 puts
        let s = NodeStore::with_spill(60, &dir);
        s.put(1, state(1.5), 1);
        s.put(2, state(2.5), 1);
        s.put(3, state(3.5), 1);
        assert!(s.spill_count() >= 1, "limit must trigger spills");
        // all three states survive, spilled or not
        for (n, v) in [(1usize, 1.5f32), (2, 2.5), (3, 3.5)] {
            let st = s.take(n).unwrap();
            assert_eq!(st[0].get(1, 1), v);
            assert_eq!(st[2].get(0, 0), v);
        }
        assert!(s.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unbounded_store_never_spills() {
        let s = NodeStore::new();
        for n in 0..10 {
            s.put(n, state(n as f32), 1);
        }
        assert_eq!(s.spill_count(), 0);
        assert_eq!(s.len(), 10);
    }
}
