//! High-level study driver: glue shared by the CLI, the examples and the
//! bench harness. Generates the SA experiments from a [`StudyConfig`],
//! instantiates the workflow, composes the reuse plan, and runs it on the
//! chosen engine.

use std::collections::HashMap;
use std::sync::Arc;

use crate::analysis::{moat_effects, screen_top_k, MoatIndices};
use crate::cache::{
    fold_keys, reference_fingerprints, tile_fingerprints, Key, ReuseCache, ScopedCounters,
};
use crate::config::{SaMethod, StudyConfig};
use crate::coordinator::{execute_study, BatchPolicy, ExecuteOptions, StudyOutcome};
use crate::data::{synth_tile, Plane, SynthConfig, TileSet};
use crate::merging::{plan_study_weighted, prune_cached, CompactGraph, FineAlgorithm, StudyPlan};
use crate::runtime::PjrtEngine;
use crate::sampling::{default_space, MoatSample, ParamSet, ParamSpace, VbdSample};
use crate::sampling::{MoatDesign, VbdDesign, CANONICAL_ACTIVE};
use crate::simulate::{simulate_plan, CostModel, SimOptions, SimReport};
use crate::workflow::{instantiate_study, paper_workflow, Evaluation, StageInstance, WorkflowSpec};
use crate::Result;

/// The SA design actually generated, kept for the estimators.
pub enum SampleInfo {
    Moat(MoatSample),
    Vbd(VbdSample, Vec<usize>),
    /// An explicit candidate list (no SA estimator applies) — what the
    /// tuning subsystem ([`crate::tune`]) prepares each optimizer
    /// generation as. Carries the number of candidate sets.
    Explicit(usize),
}

impl SampleInfo {
    /// Number of distinct parameter sets in the design.
    pub fn n_sets(&self) -> usize {
        match self {
            SampleInfo::Moat(s) => s.sets.len(),
            SampleInfo::Vbd(s, _) => s.sets.len(),
            SampleInfo::Explicit(n) => *n,
        }
    }
}

/// A fully instantiated study, ready for planning and execution.
pub struct PreparedStudy {
    pub space: ParamSpace,
    pub workflow: WorkflowSpec,
    pub sample: SampleInfo,
    pub evals: Vec<Evaluation>,
    pub instances: Vec<StageInstance>,
    pub graph: CompactGraph,
}

impl PreparedStudy {
    /// Compose the two-level reuse plan per the config's algorithm. The
    /// cost-balanced TRTMA prices tasks with the Table-6 model by
    /// default; use [`PreparedStudy::plan_with_model`] to supply a
    /// measured model.
    pub fn plan(&self, cfg: &StudyConfig) -> StudyPlan {
        self.plan_with_model(cfg, &crate::simulate::default_cost_model())
    }

    /// [`PreparedStudy::plan`] with an explicit per-task cost model
    /// (only [`FineAlgorithm::TrtmaCost`] consults it).
    pub fn plan_with_model(&self, cfg: &StudyConfig, model: &CostModel) -> StudyPlan {
        let costs: HashMap<String, f64> = if matches!(cfg.algorithm, FineAlgorithm::TrtmaCost(_)) {
            model.rows().into_iter().collect()
        } else {
            HashMap::new()
        };
        plan_study_weighted(&self.graph, &self.instances, cfg.algorithm, &costs)
    }

    /// Number of workflow evaluations (sets × tiles).
    pub fn n_evals(&self) -> usize {
        self.evals.len()
    }
}

/// Generate the experiment (parameter sets) for a config. For VBD the
/// active set defaults to the canonical top-8 of the paper (G1, G2 &co)
/// unless a MOAT screen is supplied via [`prepare_with_active`].
pub fn prepare(cfg: &StudyConfig) -> PreparedStudy {
    prepare_with_active(cfg, None)
}

/// Like [`prepare`], with an explicit VBD active-parameter set.
pub fn prepare_with_active(cfg: &StudyConfig, active: Option<Vec<usize>>) -> PreparedStudy {
    let space = default_space();
    let workflow = study_workflow(cfg, &space);
    let mut sampler = cfg.sampler.build(cfg.seed);

    let (sets, sample) = match cfg.method {
        SaMethod::Moat { r } => {
            let s = MoatDesign::new(r).generate(&space, sampler.as_mut(), cfg.seed);
            (s.sets.clone(), SampleInfo::Moat(s))
        }
        SaMethod::Vbd { n, k_active } => {
            // paper Table 2: the 8 most influential parameters survive the
            // MOAT screen — T2, G1, G2, minS, maxS, minSPL, RC, WConn
            let act = active
                .unwrap_or_else(|| CANONICAL_ACTIVE.iter().copied().take(k_active).collect());
            let s = VbdDesign::new(n).generate(&space, &act, sampler.as_mut());
            (s.sets.clone(), SampleInfo::Vbd(s, act))
        }
    };
    finish_prepare(cfg, space, workflow, &sets, sample)
}

/// Prepare an explicit candidate list as one study — the tuning
/// subsystem's entry point ([`crate::tune`]): a whole optimizer
/// generation becomes ONE multi-unit study, so stage/task merging and
/// frontier batching stack sibling candidates exactly as they stack an
/// SA design's parameter sets. `cfg.method`/`cfg.sampler` are ignored.
pub fn prepare_candidates(cfg: &StudyConfig, sets: &[ParamSet]) -> PreparedStudy {
    let space = default_space();
    let workflow = study_workflow(cfg, &space);
    finish_prepare(cfg, space, workflow, sets, SampleInfo::Explicit(sets.len()))
}

/// The workflow a config names: an explicit descriptor file, or the
/// built-in paper workflow. Public so the tuning objective
/// ([`crate::tune`]) can price a candidate's task chain with a
/// [`CostModel`] without preparing a study first.
pub fn study_workflow(cfg: &StudyConfig, space: &ParamSpace) -> WorkflowSpec {
    match &cfg.workflow_file {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .unwrap_or_else(|e| panic!("cannot read workflow file `{path}`: {e}"));
            crate::workflow::parse_workflow_file(&text, space)
                .unwrap_or_else(|e| panic!("invalid workflow file `{path}`: {e}"))
        }
        None => paper_workflow(),
    }
}

/// Shared tail of every `prepare*` flavor: lay the parameter sets out
/// set-major over the tiles, instantiate, and build the compact graph.
fn finish_prepare(
    cfg: &StudyConfig,
    space: ParamSpace,
    workflow: WorkflowSpec,
    sets: &[ParamSet],
    sample: SampleInfo,
) -> PreparedStudy {
    // set-major evaluation layout: eval(set s, tile t) = s·tiles + t
    let mut evals = Vec::with_capacity(sets.len() * cfg.tiles);
    for (s, set) in sets.iter().enumerate() {
        for t in 0..cfg.tiles {
            evals.push(Evaluation { id: s * cfg.tiles + t, tile: t as u64, params: set.clone() });
        }
    }
    let instances = instantiate_study(&workflow, &evals);
    let graph = CompactGraph::build(&instances, cfg.coarse);
    PreparedStudy { space, workflow, sample, evals, instances, graph }
}

/// Average per-set outputs over tiles (evaluations are set-major).
pub fn y_per_set(y: &[f64], n_sets: usize, tiles: usize) -> Vec<f64> {
    assert_eq!(y.len(), n_sets * tiles);
    (0..n_sets)
        .map(|s| y[s * tiles..(s + 1) * tiles].iter().sum::<f64>() / tiles as f64)
        .collect()
}

/// Deterministic synthetic tiles for a study (tile ids `0..cfg.tiles`).
pub fn make_tiles(cfg: &StudyConfig, height: usize, width: usize) -> HashMap<u64, TileSet> {
    (0..cfg.tiles as u64)
        .map(|id| {
            let seed = cfg.seed ^ (id << 17) ^ 0x7469;
            (id, synth_tile(&SynthConfig::new(height, width, seed)))
        })
        .collect()
}

/// Build the reference masks: the workflow run with the application
/// default parameters on every tile (paper §4.1: "a reference mask set,
/// created using the application default parameters").
pub fn reference_masks(
    engine: &mut PjrtEngine,
    space: &ParamSpace,
    workflow: &WorkflowSpec,
    tiles: &HashMap<u64, TileSet>,
) -> Result<HashMap<u64, Plane>> {
    let defaults = space.defaults();
    let mut task_params: HashMap<String, Vec<f32>> = HashMap::new();
    for stage in &workflow.stages {
        for t in &stage.tasks {
            task_params
                .insert(t.name.clone(), t.project(&defaults).iter().map(|&v| v as f32).collect());
        }
    }
    let mut refs = HashMap::new();
    for (&id, tile) in tiles {
        let state = engine.run_chain(tile, &task_params)?;
        refs.insert(id, state[1].clone()); // plane 1 carries the label mask
    }
    Ok(refs)
}

/// Build the cross-study reuse cache a config asks for (`None` when the
/// cache is disabled). Hold the returned `Arc` across studies — that is
/// what makes the reuse *cross*-study.
pub fn build_cache(cfg: &StudyConfig) -> Option<Arc<ReuseCache>> {
    if !cfg.cache.enabled {
        return None;
    }
    let mut cc = cfg.cache.to_cache_config();
    cc.faults = cfg.faults.clone();
    Some(Arc::new(ReuseCache::new(cc)))
}

/// The fixed per-study runtime inputs: synthetic tiles, reference masks,
/// and the artifact identity the cache keys root at. Build once with
/// [`make_inputs`] and share between a planning probe and one or more
/// executions over the same tiles — it costs an engine load plus a full
/// reference-chain run per tile, which callers should not pay twice.
pub struct StudyInputs {
    pub tiles: HashMap<u64, TileSet>,
    pub references: HashMap<u64, Plane>,
    pub compare_task: String,
    art_fp: u64,
}

/// Build the runtime inputs for a prepared study (tiles, reference
/// masks, artifact fingerprint), loading a fresh engine.
pub fn make_inputs(cfg: &StudyConfig, prepared: &PreparedStudy) -> Result<StudyInputs> {
    let mut engine = PjrtEngine::load(&cfg.artifacts_dir)?;
    make_inputs_with_engine(cfg, prepared, &mut engine)
}

/// [`make_inputs`] over an already-loaded engine — the multi-tenant
/// service reuses its process-lifetime leader engine here instead of
/// paying a load + compile per study. The engine must have been loaded
/// from the same artifacts the study will execute with.
pub fn make_inputs_with_engine(
    cfg: &StudyConfig,
    prepared: &PreparedStudy,
    engine: &mut PjrtEngine,
) -> Result<StudyInputs> {
    let (h, w) = engine.tile_shape();
    let tiles = make_tiles(cfg, h, w);
    let references = reference_masks(engine, &prepared.space, &prepared.workflow, &tiles)?;
    Ok(StudyInputs {
        tiles,
        references,
        compare_task: engine.manifest().compare_task.clone(),
        art_fp: engine.manifest().fingerprint(),
    })
}

/// Tile content fingerprints folded with the artifact fingerprint — the
/// exact cache-key roots `execute_study` derives internally.
fn keyed_tile_fps(inputs: &StudyInputs) -> HashMap<u64, Key> {
    let mut fps = tile_fingerprints(&inputs.tiles);
    for fp in fps.values_mut() {
        *fp = fold_keys(Key::from(inputs.art_fp), *fp);
    }
    fps
}

/// Run a prepared study for real on PJRT workers. When the config enables
/// the reuse cache, a fresh cache is built for this run (its disk tier,
/// if configured, still persists across runs); to share one in-memory
/// cache across studies use [`run_pjrt_with_cache`].
pub fn run_pjrt(
    cfg: &StudyConfig,
    prepared: &PreparedStudy,
    plan: &StudyPlan,
) -> Result<StudyOutcome> {
    run_pjrt_with_cache(cfg, prepared, plan, build_cache(cfg))
}

/// [`run_pjrt`] with an explicit (usually study-surviving) reuse cache.
pub fn run_pjrt_with_cache(
    cfg: &StudyConfig,
    prepared: &PreparedStudy,
    plan: &StudyPlan,
    cache: Option<Arc<ReuseCache>>,
) -> Result<StudyOutcome> {
    let inputs = make_inputs(cfg, prepared)?;
    run_pjrt_with_inputs(cfg, prepared, plan, cache, &inputs)
}

/// [`run_pjrt_with_cache`] over pre-built [`StudyInputs`] (the
/// probe-then-run flow builds inputs once and passes them to both).
/// `inputs` must come from the same artifacts dir and tile config.
pub fn run_pjrt_with_inputs(
    cfg: &StudyConfig,
    prepared: &PreparedStudy,
    plan: &StudyPlan,
    cache: Option<Arc<ReuseCache>>,
    inputs: &StudyInputs,
) -> Result<StudyOutcome> {
    run_pjrt_with_inputs_scoped(cfg, prepared, plan, cache, None, inputs)
}

/// [`run_pjrt_with_inputs`] accounting the execution's cache traffic
/// under a per-tenant [`ScopedCounters`] scope (multi-tenant serving;
/// see [`crate::serve`]). `scope` is ignored without a cache.
pub fn run_pjrt_with_inputs_scoped(
    cfg: &StudyConfig,
    prepared: &PreparedStudy,
    plan: &StudyPlan,
    cache: Option<Arc<ReuseCache>>,
    scope: Option<Arc<ScopedCounters>>,
    inputs: &StudyInputs,
) -> Result<StudyOutcome> {
    let mut opts = ExecuteOptions::new(cfg.workers, &cfg.artifacts_dir)
        .with_batch(BatchPolicy::new(cfg.batch_width))
        .with_faults(cfg.faults.clone())
        .with_obs(cfg.obs.clone(), cfg.trace.clone());
    if let Some(cache) = cache {
        opts = opts.with_cache(cache);
        if let Some(scope) = scope {
            opts = opts.with_cache_scope(scope);
        }
    }
    execute_study(
        &opts,
        plan,
        &prepared.graph,
        &prepared.instances,
        &inputs.tiles,
        &inputs.references,
        prepared.n_evals(),
    )
}

/// Cache-aware planning pass over a prepared study: probes `cache` for
/// every planned task and subtracts predicted hits from the unit costs
/// (see [`crate::merging::prune_cached`]). Returns the number of tasks
/// predicted to be served by the cache.
pub fn prune_plan_with_inputs(
    prepared: &PreparedStudy,
    plan: &mut StudyPlan,
    cache: &ReuseCache,
    inputs: &StudyInputs,
) -> usize {
    prune_cached(
        plan,
        &prepared.graph,
        &prepared.instances,
        cache,
        &keyed_tile_fps(inputs),
        &reference_fingerprints(&inputs.references),
        &inputs.compare_task,
    )
}

/// [`prune_plan_with_inputs`] building its own inputs (pays the engine
/// load + reference chain; prefer sharing [`StudyInputs`] with the
/// execution when both run).
pub fn prune_plan_with_cache(
    cfg: &StudyConfig,
    prepared: &PreparedStudy,
    plan: &mut StudyPlan,
    cache: &ReuseCache,
) -> Result<usize> {
    let inputs = make_inputs(cfg, prepared)?;
    Ok(prune_plan_with_inputs(prepared, plan, cache, &inputs))
}

/// Run a prepared study through the discrete-event simulator.
pub fn run_sim(
    prepared: &PreparedStudy,
    plan: &StudyPlan,
    model: &CostModel,
    opts: &SimOptions,
) -> SimReport {
    simulate_plan(plan, &prepared.graph, &prepared.instances, model, opts)
}

/// The paper's two-phase flow in one call: MOAT screen → top-k active
/// parameters (plus the MOAT indices for reporting).
pub fn moat_screen(
    cfg: &StudyConfig,
    prepared: &PreparedStudy,
    y: &[f64],
    k: usize,
) -> (MoatIndices, Vec<usize>) {
    let SampleInfo::Moat(sample) = &prepared.sample else {
        panic!("moat_screen requires a MOAT study");
    };
    let y_sets = y_per_set(y, sample.sets.len(), cfg.tiles);
    let idx = moat_effects(sample, &y_sets, prepared.space.dim());
    let top = screen_top_k(&idx, k);
    (idx, top)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SamplerKind;
    use crate::merging::FineAlgorithm;
    use crate::simulate::default_cost_model;

    fn cfg_moat(r: usize) -> StudyConfig {
        StudyConfig {
            method: SaMethod::Moat { r },
            sampler: SamplerKind::Qmc,
            algorithm: FineAlgorithm::Rtma(7),
            ..StudyConfig::default()
        }
    }

    #[test]
    fn prepare_moat_layout() {
        let cfg = cfg_moat(3);
        let p = prepare(&cfg);
        assert_eq!(p.sample.n_sets(), 3 * 16);
        assert_eq!(p.n_evals(), 48);
        assert_eq!(p.instances.len(), 48 * 3);
        let plan = p.plan(&cfg);
        plan.assert_valid(&p.graph);
        assert!(plan.fine_reuse() > 0.0, "MOAT studies must expose reuse");
    }

    #[test]
    fn prepare_vbd_uses_canonical_actives() {
        let cfg = StudyConfig {
            method: SaMethod::Vbd { n: 10, k_active: 8 },
            ..StudyConfig::default()
        };
        let p = prepare(&cfg);
        let SampleInfo::Vbd(s, act) = &p.sample else { panic!() };
        assert_eq!(act, &vec![4, 5, 6, 7, 8, 9, 13, 14]);
        assert_eq!(s.sample_size(), 10 * 10);
    }

    #[test]
    fn prepare_candidates_layout_matches_explicit_sets() {
        let cfg = StudyConfig { tiles: 2, ..StudyConfig::default() };
        let space = default_space();
        let mut varied = space.defaults();
        varied[5] = 10.0;
        let sets = vec![space.defaults(), varied];
        let p = prepare_candidates(&cfg, &sets);
        assert_eq!(p.sample.n_sets(), 2);
        assert_eq!(p.n_evals(), 4);
        assert_eq!(p.evals[1].tile, 1);
        assert_eq!(p.evals[2].params, sets[1]);
        let plan = p.plan(&cfg);
        plan.assert_valid(&p.graph);
    }

    #[test]
    fn sim_run_end_to_end() {
        let cfg = cfg_moat(4);
        let p = prepare(&cfg);
        let plan = p.plan(&cfg);
        let opts = crate::simulate::SimOptions::new(cfg.workers).with_cores(16);
        let r = run_sim(&p, &plan, &default_cost_model(), &opts);
        assert!(r.makespan > 0.0);
        assert_eq!(r.tasks, plan.tasks_to_execute());
    }

    #[test]
    fn y_per_set_averages_tiles() {
        let y = vec![1.0, 3.0, 5.0, 7.0];
        assert_eq!(y_per_set(&y, 2, 2), vec![2.0, 6.0]);
        assert_eq!(y_per_set(&y, 4, 1), y);
    }

    #[test]
    fn multi_tile_evals_are_set_major() {
        let cfg = StudyConfig { tiles: 3, ..cfg_moat(2) };
        let p = prepare(&cfg);
        assert_eq!(p.n_evals(), 2 * 16 * 3);
        assert_eq!(p.evals[4].tile, 1); // set 1, tile 1
        assert_eq!(p.evals[4].params, p.evals[3].params);
    }
}
