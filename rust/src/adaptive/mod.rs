//! Run-time adaptive sensitivity analysis (arXiv 1910.14548): execute an
//! SA design one unit at a time — a MOAT trajectory, a VBD j-block —
//! feeding each unit's outputs into a streaming estimator
//! ([`StreamingMoat`] / [`StreamingVbd`]), and once a parameter's
//! confidence interval shows it non-significant at the configured
//! threshold, stop paying for the evaluations only that parameter needs.
//!
//! Pruned evaluations are never silently dropped: every one is counted
//! (`pruned` on the outcome, the job report, the tenant bill and the
//! service bill), its slot in the output vector stays at the 0.0
//! sentinel, and the per-set `survived` mask says exactly which results
//! are real. The safety contract — proved by `tests/prop_adaptive.rs` —
//! is that every *surviving* evaluation's result is bit-identical to the
//! same evaluation in a full non-adaptive run at every batch width, and
//! that `threshold=0` prunes nothing (the CI upper bound is never
//! negative), making the adaptive path an exact superset of the
//! exhaustive one.
//!
//! What gets pruned:
//!
//! * **MOAT** — pruning parameter `p` drops the evaluations whose only
//!   purpose is measuring `p`'s elementary effect: evaluation `i` of a
//!   trajectory survives iff some *unpruned* step is adjacent to it
//!   (step `i-1` or step `i`). Interior evaluations shared by two steps
//!   survive until both neighbors are pruned.
//! * **VBD** — the `A_j`/`B_j` evaluations always run (every index needs
//!   them); pruning parameter `i` drops the `AB(i, j)` evaluations of
//!   blocks not yet launched. The pruned parameter keeps its estimate
//!   over the blocks it did observe.
//!
//! Speculative execution — the other half of the run-time optimization
//! story — lives in [`crate::serve`]: idle service workers pre-execute a
//! tuner's predicted next generation through the normal single-flight
//! cache path, so a correct guess is a warm hit and a wrong guess is
//! just a pre-warmed cache entry, never a changed result.

mod stream;

pub use stream::{StreamingMoat, StreamingVbd, Z95};

use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::Duration;

use crate::analysis::{MoatIndices, SobolIndices};
use crate::cache::{ReuseCache, ScopedCounters};
use crate::config::StudyConfig;
use crate::driver::{
    build_cache, make_inputs, prepare, prepare_candidates, prune_plan_with_inputs,
    run_pjrt_with_inputs_scoped, y_per_set, SampleInfo, StudyInputs,
};
use crate::sampling::ParamSet;
use crate::Result;

/// The adaptive-execution surface of a study config
/// (`adaptive=on|off threshold= min-samples=`).
#[derive(Clone, Debug, PartialEq)]
pub struct AdaptiveOptions {
    /// Run the study through the adaptive unit-at-a-time path.
    pub enabled: bool,
    /// Prune a parameter once its index's 95% CI upper bound falls
    /// below this. 0.0 (the default) never prunes — the CI upper bound
    /// is never negative — so `adaptive=on` alone only changes
    /// execution order, not coverage.
    pub threshold: f64,
    /// Units (trajectories / j-blocks) that must complete before the
    /// pruner may act; CIs over fewer samples are too wide to trust.
    pub min_samples: usize,
}

impl Default for AdaptiveOptions {
    fn default() -> Self {
        Self { enabled: false, threshold: 0.0, min_samples: 4 }
    }
}

/// The final streaming estimate of an adaptive run.
#[derive(Clone, Debug)]
pub enum AdaptiveEstimate {
    Moat(MoatIndices),
    Vbd(SobolIndices),
}

/// What an adaptive run produced: the (partially filled) output vector,
/// the survival mask saying which slots are real, the pruning account,
/// and the final streaming estimate.
#[derive(Clone, Debug)]
pub struct AdaptiveOutcome {
    /// Per-evaluation scalar outputs over the FULL design
    /// (`n_sets × tiles`, set-major, like a non-adaptive run). Pruned
    /// evaluations hold the 0.0 sentinel; consult [`Self::survived`].
    pub y: Vec<f64>,
    /// Per-*set* survival mask (an evaluation survived iff its set did).
    pub survived: Vec<bool>,
    /// Evaluations (set × tile) cancelled before launch — the number a
    /// non-adaptive run would have paid for on top of what this one did.
    pub pruned: u64,
    /// Parameters the pruner ruled non-significant, in pruning order.
    pub pruned_params: Vec<usize>,
    /// The streaming estimate over everything that executed.
    pub estimate: AdaptiveEstimate,
    /// Kernel launches actually paid (sum over unit executions).
    pub launches: u64,
    /// Tasks served from the reuse cache instead of launched.
    pub cached_tasks: u64,
    /// Wall time summed over unit executions.
    pub wall: Duration,
}

/// Run a study adaptively, standalone: builds the cache and inputs the
/// config asks for. The serving path uses [`run_adaptive_scoped`] with
/// its shared cache and tenant scope instead.
pub fn run_adaptive(cfg: &StudyConfig) -> Result<AdaptiveOutcome> {
    let prepared = prepare(cfg);
    let inputs = make_inputs(cfg, &prepared)?;
    run_adaptive_scoped(cfg, build_cache(cfg), None, &inputs)
}

/// Run a study adaptively over pre-built inputs, accounting cache
/// traffic under `scope` (both optional, exactly like
/// [`run_pjrt_with_inputs_scoped`]). Executes the design one unit at a
/// time through the normal prepare → plan → execute path, so every
/// surviving evaluation takes the same code path — and produces the
/// same bytes — as a non-adaptive run.
pub fn run_adaptive_scoped(
    cfg: &StudyConfig,
    cache: Option<Arc<ReuseCache>>,
    scope: Option<Arc<ScopedCounters>>,
    inputs: &StudyInputs,
) -> Result<AdaptiveOutcome> {
    let prepared = prepare(cfg);
    match &prepared.sample {
        SampleInfo::Moat(_) => run_adaptive_moat(cfg, &prepared, cache, scope, inputs),
        SampleInfo::Vbd(..) => run_adaptive_vbd(cfg, &prepared, cache, scope, inputs),
        SampleInfo::Explicit(_) => unreachable!("prepare() never yields Explicit"),
    }
}

/// Execute `sets` (a unit's surviving parameter sets) as one candidate
/// study and scatter the per-set outputs into `y_full` at `globals`,
/// marking them survived. Returns (launches, cached, wall).
#[allow(clippy::too_many_arguments)]
fn run_unit(
    cfg: &StudyConfig,
    sets: Vec<ParamSet>,
    globals: &[usize],
    cache: &Option<Arc<ReuseCache>>,
    scope: &Option<Arc<ScopedCounters>>,
    inputs: &StudyInputs,
    y_full: &mut [f64],
    y_sets_full: &mut [f64],
    survived: &mut [bool],
) -> Result<(u64, u64, Duration)> {
    if sets.is_empty() {
        return Ok((0, 0, Duration::ZERO));
    }
    let n_local = sets.len();
    let unit = prepare_candidates(cfg, &sets);
    let mut plan = unit.plan(cfg);
    if let Some(c) = cache {
        prune_plan_with_inputs(&unit, &mut plan, c, inputs);
    }
    let out = run_pjrt_with_inputs_scoped(cfg, &unit, &plan, cache.clone(), scope.clone(), inputs)?;
    let y_sets = y_per_set(&out.y, n_local, cfg.tiles);
    for (local, &global) in globals.iter().enumerate() {
        y_sets_full[global] = y_sets[local];
        survived[global] = true;
        for t in 0..cfg.tiles {
            y_full[global * cfg.tiles + t] = out.y[local * cfg.tiles + t];
        }
    }
    Ok((out.timer.launches(), out.timer.cached_served(), out.wall))
}

fn run_adaptive_moat(
    cfg: &StudyConfig,
    prepared: &crate::driver::PreparedStudy,
    cache: Option<Arc<ReuseCache>>,
    scope: Option<Arc<ScopedCounters>>,
    inputs: &StudyInputs,
) -> Result<AdaptiveOutcome> {
    let SampleInfo::Moat(sample) = &prepared.sample else { unreachable!() };
    let k = prepared.space.dim();
    let n_sets = sample.sets.len();
    let opts = &cfg.adaptive;

    let mut stream = StreamingMoat::new(k);
    let mut pruned: BTreeSet<usize> = BTreeSet::new();
    let mut pruned_params = Vec::new();
    let mut y_full = vec![0.0; n_sets * cfg.tiles];
    let mut y_sets_full = vec![0.0; n_sets];
    let mut survived = vec![false; n_sets];
    let (mut launches, mut cached, mut wall) = (0u64, 0u64, Duration::ZERO);

    for t in &sample.trajectories {
        // evaluation i survives iff an unpruned step is adjacent to it
        let mut sets = Vec::new();
        let mut globals = Vec::new();
        for i in 0..=k {
            let prev_live = i > 0 && !pruned.contains(&t.steps[i - 1].param);
            let next_live = i < k && !pruned.contains(&t.steps[i].param);
            if prev_live || next_live {
                globals.push(t.first_eval + i);
                sets.push(sample.sets[t.first_eval + i].clone());
            }
        }
        let (l, c, w) = run_unit(
            cfg,
            sets,
            &globals,
            &cache,
            &scope,
            inputs,
            &mut y_full,
            &mut y_sets_full,
            &mut survived,
        )?;
        launches += l;
        cached += c;
        wall += w;

        stream.update(t, &y_sets_full, &survived);
        if stream.trajectories() >= opts.min_samples {
            for p in 0..k {
                if !pruned.contains(&p) && stream.mu_star_upper(p) < opts.threshold {
                    pruned.insert(p);
                    pruned_params.push(p);
                }
            }
        }
    }

    let pruned_evals = survived.iter().filter(|s| !**s).count() as u64 * cfg.tiles as u64;
    Ok(AdaptiveOutcome {
        y: y_full,
        survived,
        pruned: pruned_evals,
        pruned_params,
        estimate: AdaptiveEstimate::Moat(stream.indices()),
        launches,
        cached_tasks: cached,
        wall,
    })
}

fn run_adaptive_vbd(
    cfg: &StudyConfig,
    prepared: &crate::driver::PreparedStudy,
    cache: Option<Arc<ReuseCache>>,
    scope: Option<Arc<ScopedCounters>>,
    inputs: &StudyInputs,
) -> Result<AdaptiveOutcome> {
    let SampleInfo::Vbd(sample, _active) = &prepared.sample else { unreachable!() };
    let k = sample.k;
    let n_sets = sample.sets.len();
    let opts = &cfg.adaptive;

    let mut stream = StreamingVbd::new(k);
    let mut pruned: BTreeSet<usize> = BTreeSet::new();
    let mut pruned_params = Vec::new();
    let mut y_full = vec![0.0; n_sets * cfg.tiles];
    let mut y_sets_full = vec![0.0; n_sets];
    let mut survived = vec![false; n_sets];
    let (mut launches, mut cached, mut wall) = (0u64, 0u64, Duration::ZERO);

    for j in 0..sample.n {
        // A_j and B_j always run; AB(i, j) only for unpruned i
        let mut globals = vec![sample.idx_a(j), sample.idx_b(j)];
        globals.extend((0..k).filter(|i| !pruned.contains(i)).map(|i| sample.idx_ab(i, j)));
        let sets: Vec<ParamSet> = globals.iter().map(|&g| sample.sets[g].clone()).collect();
        let (l, c, w) = run_unit(
            cfg,
            sets,
            &globals,
            &cache,
            &scope,
            inputs,
            &mut y_full,
            &mut y_sets_full,
            &mut survived,
        )?;
        launches += l;
        cached += c;
        wall += w;

        let fab: Vec<Option<f64>> = (0..k)
            .map(|i| survived[sample.idx_ab(i, j)].then(|| y_sets_full[sample.idx_ab(i, j)]))
            .collect();
        stream.update(y_sets_full[sample.idx_a(j)], y_sets_full[sample.idx_b(j)], &fab);
        if stream.blocks() >= opts.min_samples {
            for i in 0..k {
                if !pruned.contains(&i) && stream.first_upper(i) < opts.threshold {
                    pruned.insert(i);
                    pruned_params.push(i);
                }
            }
        }
    }

    let pruned_evals = survived.iter().filter(|s| !**s).count() as u64 * cfg.tiles as u64;
    Ok(AdaptiveOutcome {
        y: y_full,
        survived,
        pruned: pruned_evals,
        pruned_params,
        estimate: AdaptiveEstimate::Vbd(stream.indices()),
        launches,
        cached_tasks: cached,
        wall,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_do_not_prune() {
        let o = AdaptiveOptions::default();
        assert!(!o.enabled);
        assert_eq!(o.threshold, 0.0);
        assert_eq!(o.min_samples, 4);
    }

    #[test]
    fn moat_survival_rule_keeps_shared_interior_evals() {
        // a 3-param trajectory: steps touch params [2, 0, 1]; pruning
        // param 0 must keep evals 1 and 2 (each adjacent to an unpruned
        // step) — only evals with NO unpruned neighbor drop
        use crate::sampling::{MoatStep, Trajectory};
        let t = Trajectory {
            first_eval: 0,
            steps: vec![
                MoatStep { param: 2, delta_norm: 0.5 },
                MoatStep { param: 0, delta_norm: 0.5 },
                MoatStep { param: 1, delta_norm: 0.5 },
            ],
        };
        let pruned: BTreeSet<usize> = [0].into_iter().collect();
        let k = 3;
        let survives: Vec<bool> = (0..=k)
            .map(|i| {
                let prev = i > 0 && !pruned.contains(&t.steps[i - 1].param);
                let next = i < k && !pruned.contains(&t.steps[i].param);
                prev || next
            })
            .collect();
        assert_eq!(survives, vec![true, true, true, true]);
        // pruning params 0 AND 2 drops eval 1 (both neighbors pruned)
        let pruned: BTreeSet<usize> = [0, 2].into_iter().collect();
        let survives: Vec<bool> = (0..=k)
            .map(|i| {
                let prev = i > 0 && !pruned.contains(&t.steps[i - 1].param);
                let next = i < k && !pruned.contains(&t.steps[i].param);
                prev || next
            })
            .collect();
        assert_eq!(survives, vec![false, false, true, true]);
    }
}
