//! Incremental sensitivity estimators.
//!
//! [`StreamingMoat`] and [`StreamingVbd`] accumulate the exact same
//! statistics as the batch estimators in [`crate::analysis`], one
//! completed unit at a time (a MOAT trajectory; a VBD j-block), so an
//! adaptive run can consult the indices — with confidence intervals —
//! after every unit instead of only at the end.
//!
//! **Bit-identity contract** (asserted by `tests/prop_adaptive.rs`):
//! after feeding the first `m` units, [`StreamingMoat::indices`] /
//! [`StreamingVbd::indices`] return bit-for-bit the values
//! [`crate::analysis::moat_effects`] / [`crate::analysis::sobol_indices`]
//! compute on the same `m`-unit prefix of the design. The streaming
//! accumulators therefore perform the *same floating-point operations in
//! the same order* as the batch code — any "equivalent" reassociation
//! would break the contract.

use crate::analysis::{MoatIndices, SobolIndices};
use crate::sampling::Trajectory;

/// z-score of the two-sided 95% confidence interval every estimator's
/// half-width uses. A pruning threshold compares against
/// `estimate + Z95 * stderr`, so a region is only ruled non-significant
/// once even the CI's upper edge sits below the threshold.
pub const Z95: f64 = 1.96;

/// Streaming Morris elementary effects: per-parameter running sums fed
/// one trajectory at a time, finalized exactly like
/// [`crate::analysis::moat_effects`].
#[derive(Clone, Debug)]
pub struct StreamingMoat {
    k: usize,
    sums: Vec<f64>,
    abs_sums: Vec<f64>,
    sq_sums: Vec<f64>,
    count: Vec<usize>,
    trajectories: usize,
}

impl StreamingMoat {
    /// `k` is the parameter-space dimension (the batch estimator's `k`).
    pub fn new(k: usize) -> Self {
        Self {
            k,
            sums: vec![0.0; k],
            abs_sums: vec![0.0; k],
            sq_sums: vec![0.0; k],
            count: vec![0; k],
            trajectories: 0,
        }
    }

    /// Fold one completed trajectory in. `y` holds the per-set outputs
    /// of the *whole design* (indexed by `trajectory.first_eval + i`);
    /// `executed[e]` says whether evaluation `e` actually ran — a step
    /// contributes its elementary effect only when both endpoints did,
    /// so pruned evaluations never poison the sums. With every
    /// evaluation executed this is exactly one trajectory's iteration
    /// of the batch loop.
    pub fn update(&mut self, trajectory: &Trajectory, y: &[f64], executed: &[bool]) {
        for (i, step) in trajectory.steps.iter().enumerate() {
            let (b, a) = (trajectory.first_eval + i, trajectory.first_eval + i + 1);
            if !executed[b] || !executed[a] {
                continue;
            }
            let ee = (y[a] - y[b]) / step.delta_norm;
            self.sums[step.param] += ee;
            self.abs_sums[step.param] += ee.abs();
            self.sq_sums[step.param] += ee * ee;
            self.count[step.param] += 1;
        }
        self.trajectories += 1;
    }

    /// Trajectories folded in so far.
    pub fn trajectories(&self) -> usize {
        self.trajectories
    }

    /// Elementary effects observed for parameter `p` so far.
    pub fn count(&self, p: usize) -> usize {
        self.count[p]
    }

    /// The indices over everything folded in so far — bit-identical to
    /// [`crate::analysis::moat_effects`] on the same prefix.
    pub fn indices(&self) -> MoatIndices {
        let mut mean = vec![0.0; self.k];
        let mut mu_star = vec![0.0; self.k];
        let mut sigma = vec![0.0; self.k];
        for p in 0..self.k {
            let n = self.count[p] as f64;
            if self.count[p] == 0 {
                continue;
            }
            mean[p] = self.sums[p] / n;
            mu_star[p] = self.abs_sums[p] / n;
            let var = (self.sq_sums[p] / n - mean[p] * mean[p]).max(0.0);
            sigma[p] = var.sqrt();
        }
        MoatIndices { mean, mu_star, sigma, count: self.count.clone() }
    }

    /// 95% CI half-width of μ*(p): `Z95 · sd(|EE_p|) / √n`. Since
    /// |EE|² = EE², the absolute effects' second moment is the same
    /// `sq_sums` the batch σ uses — no extra running state is needed.
    /// `f64::INFINITY` with no observations (nothing can be ruled out).
    pub fn mu_star_half_width(&self, p: usize) -> f64 {
        let n = self.count[p] as f64;
        if self.count[p] == 0 {
            return f64::INFINITY;
        }
        let mu_star = self.abs_sums[p] / n;
        let var = (self.sq_sums[p] / n - mu_star * mu_star).max(0.0);
        Z95 * var.sqrt() / n.sqrt()
    }

    /// Upper edge of μ*(p)'s 95% CI — what the pruner compares against
    /// its threshold. Always ≥ μ* ≥ 0, so a threshold of 0 never prunes.
    pub fn mu_star_upper(&self, p: usize) -> f64 {
        let n = self.count[p] as f64;
        if self.count[p] == 0 {
            return f64::INFINITY;
        }
        self.abs_sums[p] / n + self.mu_star_half_width(p)
    }
}

/// Streaming Saltelli/Jansen VBD estimator: stores the `f_A`, `f_B` and
/// `f_ABi` evaluations of every completed j-block and recomputes the
/// indices over the prefix with exactly the batch formulas.
///
/// Unlike MOAT (whose per-parameter sums are associative in trajectory
/// order), the Sobol estimators normalize by the prefix variance, which
/// changes with every block — so the streaming form keeps the per-block
/// outputs (three `f64`s per block per parameter, trivial next to the
/// evaluations themselves) and re-runs the batch arithmetic on demand.
#[derive(Clone, Debug)]
pub struct StreamingVbd {
    k: usize,
    fa: Vec<f64>,
    fb: Vec<f64>,
    /// `fab[i][j]`: f(AB_i) of block j — `None` when AB(i, j) was pruned.
    fab: Vec<Vec<Option<f64>>>,
}

impl StreamingVbd {
    /// `k` is the number of active parameters (the design's `k`).
    pub fn new(k: usize) -> Self {
        Self { k, fa: Vec::new(), fb: Vec::new(), fab: vec![Vec::new(); k] }
    }

    /// Fold one completed j-block in: the A and B outputs plus the
    /// per-parameter AB outputs (`None` for parameters whose AB
    /// evaluation was pruned away).
    pub fn update(&mut self, fa: f64, fb: f64, fab: &[Option<f64>]) {
        assert_eq!(fab.len(), self.k, "one AB output slot per active parameter");
        self.fa.push(fa);
        self.fb.push(fb);
        for (i, v) in fab.iter().enumerate() {
            self.fab[i].push(*v);
        }
    }

    /// j-blocks folded in so far.
    pub fn blocks(&self) -> usize {
        self.fa.len()
    }

    /// AB observations for parameter `i` so far (< `blocks()` once the
    /// pruner starts dropping AB(i, ·) evaluations).
    pub fn ab_count(&self, i: usize) -> usize {
        self.fab[i].iter().filter(|v| v.is_some()).count()
    }

    /// The indices over the prefix folded in so far. With no pruning
    /// this is bit-identical to [`crate::analysis::sobol_indices`] on
    /// the same `n = blocks()` prefix of the design; a pruned parameter
    /// keeps the estimate over the blocks it did observe (its per-block
    /// terms are simply absent from its sums — count `ab_count(i)`).
    pub fn indices(&self) -> SobolIndices {
        let n = self.fa.len();
        // identical accumulation order to the batch estimator: mean and
        // variance over A ∪ B as one chained pass
        let all: Vec<f64> = self.fa.iter().chain(&self.fb).copied().collect();
        let mean = all.iter().sum::<f64>() / (all.len() as f64).max(1.0);
        let variance = if all.is_empty() {
            0.0
        } else {
            all.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / all.len() as f64
        };
        let mut first = vec![0.0; self.k];
        let mut total = vec![0.0; self.k];
        if variance > 1e-300 {
            for i in 0..self.k {
                let mut s = 0.0;
                let mut t = 0.0;
                let mut m = 0usize;
                for j in 0..n {
                    let Some(fab) = self.fab[i][j] else { continue };
                    s += self.fb[j] * (fab - self.fa[j]);
                    t += (self.fa[j] - fab) * (self.fa[j] - fab);
                    m += 1;
                }
                if m > 0 {
                    first[i] = s / (m as f64 * variance);
                    total[i] = t / (2.0 * m as f64 * variance);
                }
            }
        }
        SobolIndices { first, total, variance }
    }

    /// 95% CI half-width of S_i: the Saltelli estimator is a mean of the
    /// per-block terms `d_ij = f_B(j) · (f_ABi(j) − f_A(j)) / V`, so its
    /// standard error is `sd(d_i·) / √m`. `f64::INFINITY` with fewer
    /// than two observations or (near-)zero variance.
    pub fn first_half_width(&self, i: usize) -> f64 {
        let idx = self.indices();
        if idx.variance <= 1e-300 {
            return f64::INFINITY;
        }
        let d: Vec<f64> = (0..self.fa.len())
            .filter_map(|j| {
                self.fab[i][j].map(|fab| self.fb[j] * (fab - self.fa[j]) / idx.variance)
            })
            .collect();
        let m = d.len();
        if m < 2 {
            return f64::INFINITY;
        }
        let mean = d.iter().sum::<f64>() / m as f64;
        let var = d.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / m as f64;
        Z95 * var.sqrt() / (m as f64).sqrt()
    }

    /// Upper edge of S_i's 95% CI — what the pruner compares against its
    /// threshold. `|S_i| + half-width`, so a threshold of 0 never prunes.
    pub fn first_upper(&self, i: usize) -> f64 {
        let half = self.first_half_width(i);
        if half.is_infinite() {
            return f64::INFINITY;
        }
        self.indices().first[i].abs() + half
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{moat_effects, sobol_indices};
    use crate::sampling::{default_space, HaltonSampler, MoatDesign, VbdDesign, VbdSample};
    use crate::testutil::splitmix64;

    fn synth_y(n: usize, seed: u64) -> Vec<f64> {
        let mut s = seed;
        (0..n).map(|_| (splitmix64(&mut s) >> 11) as f64 / (1u64 << 53) as f64).collect()
    }

    #[test]
    fn streaming_moat_is_bit_identical_to_batch_on_every_prefix() {
        let space = default_space();
        let sample = MoatDesign::new(6).generate(&space, &mut HaltonSampler::new(3), 17);
        let y = synth_y(sample.sets.len(), 41);
        let executed = vec![true; sample.sets.len()];
        let mut stream = StreamingMoat::new(space.dim());
        for (m, t) in sample.trajectories.iter().enumerate() {
            stream.update(t, &y, &executed);
            let k = space.dim();
            let prefix = crate::sampling::MoatSample {
                sets: sample.sets[..(m + 1) * (k + 1)].to_vec(),
                trajectories: sample.trajectories[..m + 1].to_vec(),
            };
            let batch = moat_effects(&prefix, &y[..(m + 1) * (k + 1)], k);
            let ours = stream.indices();
            for p in 0..k {
                assert_eq!(ours.mean[p].to_bits(), batch.mean[p].to_bits(), "mean[{p}] @ {m}");
                assert_eq!(ours.mu_star[p].to_bits(), batch.mu_star[p].to_bits(), "mu*[{p}]");
                assert_eq!(ours.sigma[p].to_bits(), batch.sigma[p].to_bits(), "sigma[{p}]");
                assert_eq!(ours.count[p], batch.count[p], "count[{p}]");
            }
        }
    }

    #[test]
    fn streaming_vbd_is_bit_identical_to_batch_on_every_prefix() {
        let space = default_space();
        let active = vec![5usize, 6, 7];
        let sample =
            VbdDesign::new(12).generate(&space, &active, &mut HaltonSampler::new(5));
        let y = synth_y(sample.sample_size(), 43);
        let mut stream = StreamingVbd::new(sample.k);
        for j in 0..sample.n {
            let fab: Vec<Option<f64>> =
                (0..sample.k).map(|i| Some(y[sample.idx_ab(i, j)])).collect();
            stream.update(y[sample.idx_a(j)], y[sample.idx_b(j)], &fab);
            let m = j + 1;
            // the same design truncated to its first m blocks
            let mut sets = Vec::new();
            let mut ty = Vec::new();
            for jj in 0..m {
                sets.push(sample.sets[sample.idx_a(jj)].clone());
                ty.push(y[sample.idx_a(jj)]);
            }
            for jj in 0..m {
                sets.push(sample.sets[sample.idx_b(jj)].clone());
                ty.push(y[sample.idx_b(jj)]);
            }
            for i in 0..sample.k {
                for jj in 0..m {
                    sets.push(sample.sets[sample.idx_ab(i, jj)].clone());
                    ty.push(y[sample.idx_ab(i, jj)]);
                }
            }
            let prefix = VbdSample { sets, n: m, k: sample.k };
            let batch = sobol_indices(&prefix, &ty);
            let ours = stream.indices();
            assert_eq!(ours.variance.to_bits(), batch.variance.to_bits(), "variance @ {m}");
            for i in 0..sample.k {
                assert_eq!(ours.first[i].to_bits(), batch.first[i].to_bits(), "S[{i}] @ {m}");
                assert_eq!(ours.total[i].to_bits(), batch.total[i].to_bits(), "ST[{i}] @ {m}");
            }
        }
    }

    #[test]
    fn moat_ci_shrinks_and_upper_bounds_mu_star() {
        let space = default_space();
        let sample = MoatDesign::new(10).generate(&space, &mut HaltonSampler::new(1), 7);
        let y = synth_y(sample.sets.len(), 97);
        let executed = vec![true; y.len()];
        let mut once = StreamingMoat::new(space.dim());
        let mut twice = StreamingMoat::new(space.dim());
        for t in &sample.trajectories {
            once.update(t, &y, &executed);
            twice.update(t, &y, &executed);
            twice.update(t, &y, &executed);
        }
        let idx = once.indices();
        for p in 0..space.dim() {
            if once.count(p) == 0 {
                continue;
            }
            assert!(once.mu_star_upper(p) >= idx.mu_star[p], "upper bounds μ*[{p}]");
            // doubling every observation keeps sd(|EE|) and halves
            // width by √2 — more samples must tighten the CI
            let (w1, w2) = (once.mu_star_half_width(p), twice.mu_star_half_width(p));
            assert!(w2 <= w1, "CI must not widen with replication: {w1} -> {w2} @ {p}");
            if w1 > 0.0 {
                assert!(w2 < w1, "CI must tighten with replication @ {p}");
            }
        }
        // an untouched parameter index cannot be ruled out
        let empty = StreamingMoat::new(3);
        assert!(empty.mu_star_upper(0).is_infinite());
    }

    #[test]
    fn vbd_pruned_parameters_keep_their_partial_estimates() {
        let space = default_space();
        let active = vec![5usize, 6];
        let sample = VbdDesign::new(8).generate(&space, &active, &mut HaltonSampler::new(9));
        let y = synth_y(sample.sample_size(), 71);
        let mut stream = StreamingVbd::new(sample.k);
        for j in 0..sample.n {
            // parameter 1's AB evaluations stop after the 4th block
            let fab: Vec<Option<f64>> = (0..sample.k)
                .map(|i| (i == 0 || j < 4).then(|| y[sample.idx_ab(i, j)]))
                .collect();
            stream.update(y[sample.idx_a(j)], y[sample.idx_b(j)], &fab);
        }
        assert_eq!(stream.ab_count(0), sample.n);
        assert_eq!(stream.ab_count(1), 4);
        let idx = stream.indices();
        assert!(idx.first[1].is_finite(), "pruned parameter keeps a finite estimate");
        assert!(stream.first_half_width(0) < f64::INFINITY);
    }
}
