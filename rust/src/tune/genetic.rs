//! Genetic-algorithm tuner: (μ+λ) selection with uniform crossover and
//! level-jitter mutation over **grid-level genomes** — every gene is an
//! index into one active parameter's discrete Table-1 grid, so the
//! population lives on exactly the quantized points the memo table and
//! the chain cache key on. Crossover recombines grid cells two good
//! parents already paid for; mutation moves at most two levels, so
//! children's task chains share long prefixes with their parents' —
//! which is what makes GA generations the highest-reuse workload of the
//! study cache.
//!
//! Determinism: all randomness flows from one [`SplitMix64`] seeded by
//! the study seed; survivor selection sorts by score with a stable
//! sort, so ties resolve by insertion order. Same seed + same scores ⇒
//! the same ask/tell trajectory, whatever the cache or batch width did.

use crate::data::SplitMix64;
use crate::sampling::{ParamSet, ParamSpace};

use super::{TuneOptions, Tuner};

/// One genome: a grid-level index per active parameter.
type Genome = Vec<usize>;

/// The GA tuner (see the module docs). `Clone` exists for
/// [`Tuner::speculate_next`]: predicting the next generation runs
/// tell → ask on a throwaway copy, leaving the real state untouched.
#[derive(Clone)]
pub struct Genetic {
    space: ParamSpace,
    active: Vec<usize>,
    defaults: ParamSet,
    pop_size: usize,
    budget: usize,
    mutation: f64,
    init_window: (f64, f64),
    rng: SplitMix64,
    asked_total: usize,
    /// Scored survivors, best first.
    population: Vec<(Genome, f64)>,
    /// The generation awaiting scores.
    pending: Vec<Genome>,
}

impl Genetic {
    /// A GA over `active` parameter indices of `space`; everything else
    /// stays at the space defaults.
    pub fn new(space: ParamSpace, active: Vec<usize>, opts: &TuneOptions, seed: u64) -> Self {
        assert!(!active.is_empty(), "GA needs at least one active parameter");
        let defaults = space.defaults();
        Self {
            space,
            active,
            defaults,
            pop_size: opts.population.max(2),
            budget: opts.budget.max(1),
            mutation: opts.mutation.clamp(0.0, 1.0),
            init_window: opts.init_window,
            rng: SplitMix64::new(seed ^ 0x6761), // domain-separated from the samplers
            asked_total: 0,
            population: Vec::new(),
            pending: Vec::new(),
        }
    }

    fn levels_of(&self, gene: usize) -> usize {
        self.space.params[self.active[gene]].levels()
    }

    fn random_genome(&mut self) -> Genome {
        let (lo, hi) = self.init_window;
        let mut genome = Vec::with_capacity(self.active.len());
        for &p in &self.active {
            let f = self.rng.uniform(lo, hi);
            genome.push(self.space.params[p].level_of_fraction(f));
        }
        genome
    }

    fn params_of(&self, genome: &[usize]) -> ParamSet {
        let mut params = self.defaults.clone();
        for (gene, &level) in genome.iter().enumerate() {
            let p = self.active[gene];
            params[p] = self.space.params[p].value_at(level);
        }
        params
    }

    /// Binary tournament on the (best-first) population: the better —
    /// i.e. lower-indexed — of two uniform draws.
    fn tournament(&mut self) -> Genome {
        let n = self.population.len();
        let a = self.rng.uniform_usize(0, n);
        let b = self.rng.uniform_usize(0, n);
        self.population[a.min(b)].0.clone()
    }

    fn child(&mut self) -> Genome {
        let pa = self.tournament();
        let pb = self.tournament();
        let mut genome = Vec::with_capacity(pa.len());
        for gene in 0..pa.len() {
            let from_a = self.rng.next_f64() < 0.5;
            genome.push(if from_a { pa[gene] } else { pb[gene] });
        }
        for gene in 0..genome.len() {
            if self.rng.next_f64() < self.mutation {
                let span = self.levels_of(gene);
                let step = 1 + self.rng.uniform_usize(0, 2); // one or two levels
                genome[gene] = if self.rng.next_f64() < 0.5 {
                    genome[gene].saturating_sub(step)
                } else {
                    (genome[gene] + step).min(span - 1)
                };
            }
        }
        genome
    }
}

impl Tuner for Genetic {
    fn name(&self) -> &'static str {
        "genetic"
    }

    fn ask(&mut self) -> Vec<ParamSet> {
        assert!(self.pending.is_empty(), "tell() the previous generation first");
        if self.asked_total >= self.budget {
            return Vec::new();
        }
        let n = if self.population.is_empty() {
            self.pop_size // the initial population
        } else {
            self.pop_size - 1 // survivors carry the elite over unchanged
        };
        let mut generation: Vec<Genome> = Vec::with_capacity(n);
        for _ in 0..n {
            let g = if self.population.is_empty() {
                self.random_genome()
            } else {
                self.child()
            };
            generation.push(g);
        }
        self.asked_total += generation.len();
        let sets = generation.iter().map(|g| self.params_of(g)).collect();
        self.pending = generation;
        sets
    }

    fn tell(&mut self, scores: &[f64]) {
        assert_eq!(scores.len(), self.pending.len(), "scores must match the asked generation");
        let children = std::mem::take(&mut self.pending);
        self.population.extend(children.into_iter().zip(scores.iter().copied()));
        // (μ+λ): parents and children compete; stable sort keeps the
        // earlier-ranked genome on score ties, so selection is
        // deterministic
        self.population.sort_by(|a, b| b.1.total_cmp(&a.1));
        self.population.truncate(self.pop_size);
    }

    fn speculate_next(&self, guessed_scores: &[f64]) -> Vec<ParamSet> {
        if guessed_scores.len() != self.pending.len() || self.pending.is_empty() {
            return Vec::new();
        }
        let mut copy = self.clone();
        copy.tell(guessed_scores);
        copy.ask()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::default_space;
    use crate::tune::TunerKind;

    fn opts(budget: usize, population: usize) -> TuneOptions {
        TuneOptions { method: TunerKind::Genetic, budget, population, ..TuneOptions::default() }
    }

    #[test]
    fn fixed_seed_trajectories_are_identical() {
        // a deterministic pseudo-score peaking at the defaults
        fn score(s: &[f64]) -> f64 {
            -(s[5] - 45.0).abs() - (s[6] - 22.0).abs()
        }
        let run = || {
            let mut ga = Genetic::new(default_space(), vec![5, 6], &opts(12, 4), 7);
            let mut asked = Vec::new();
            loop {
                let generation = ga.ask();
                if generation.is_empty() {
                    break;
                }
                let scores: Vec<f64> = generation.iter().map(|s| score(s)).collect();
                asked.push(generation);
                ga.tell(&scores);
            }
            asked
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same seed, same trajectory");
        assert!(a.len() >= 3, "budget 12 at population 4 runs several generations");
        assert_eq!(a[0].len(), 4);
        assert_eq!(a[1].len(), 3, "later generations re-breed around the elite");
    }

    #[test]
    fn genomes_stay_on_grid_and_respect_active_dims() {
        let space = default_space();
        let mut ga = Genetic::new(space.clone(), vec![5], &opts(8, 4), 1);
        let generation = ga.ask();
        for set in &generation {
            space.validate(set).expect("candidates lie on the grids");
            for (i, v) in set.iter().enumerate() {
                if i != 5 {
                    assert_eq!(*v, space.defaults()[i], "inactive dims stay at defaults");
                }
            }
        }
    }

    #[test]
    fn speculate_next_predicts_without_advancing_state() {
        let mut ga = Genetic::new(default_space(), vec![5, 6], &opts(12, 4), 7);
        let g1 = ga.ask();
        let guess = vec![0.0; g1.len()];
        let predicted = ga.speculate_next(&guess);
        assert!(!predicted.is_empty());
        assert_eq!(predicted, ga.speculate_next(&guess), "speculation is pure");
        // telling the guessed scores for real yields exactly the prediction
        ga.tell(&guess);
        assert_eq!(ga.ask(), predicted);
    }

    #[test]
    fn budget_bounds_total_asks() {
        let mut ga = Genetic::new(default_space(), vec![5, 6], &opts(5, 4), 3);
        let mut total = 0;
        loop {
            let generation = ga.ask();
            if generation.is_empty() {
                break;
            }
            total += generation.len();
            let scores = vec![0.0; generation.len()];
            ga.tell(&scores);
        }
        // generations are atomic: the last may overshoot by < population
        assert!(total >= 5 && total < 5 + 4, "asked {total}");
    }
}
