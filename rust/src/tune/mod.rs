//! Parameter auto-tuning: optimizer-driven studies riding the shared
//! reuse cache.
//!
//! The paper's SA studies *measure* parameter influence; the natural
//! next workload — the one its successors run ("Tuning for Tissue Image
//! Segmentation Workflows for Accuracy and Performance") — *optimizes*
//! the parameters, and run-time analyses of those searches show
//! Nelder-Mead and genetic optimizers revisit quantized parameter
//! points constantly, making tuning the highest-reuse workload of all.
//! This module wraps the existing study machinery in that loop:
//!
//! * a [`Tuner`] trait (ask a generation of candidates / tell their
//!   scores) with two implementations — [`NelderMead`] (speculatively
//!   batched downhill simplex) and [`Genetic`] (crossover + mutation
//!   over grid-level genomes);
//! * an objective layer ([`Objective`], [`CandidateEvaluator`]) that
//!   scores each generation by running it as ONE multi-unit study
//!   through [`crate::driver::run_pjrt_with_inputs_scoped`] — Dice or
//!   Jaccard against the reference masks, optionally cost-penalized by
//!   a [`crate::simulate::CostModel`] — so frontier batching stacks
//!   sibling candidates into batched kernel launches;
//! * a per-run **memo table** keyed by the quantized 128-bit
//!   [`crate::cache::candidate_key`], so a revisited point skips even
//!   the study setup, while partial chain overlap between neighboring
//!   candidates hits the shared [`crate::cache::ReuseCache`] exactly as
//!   the paper predicts.
//!
//! Entry points: [`run_tune`] (explicit cache/scope/inputs — what the
//! multi-tenant service's tuning job kind calls) and
//! [`run_tune_standalone`] (builds its own; the `tune` CLI mode).
//! Determinism: for a fixed seed the whole run is bit-identical across
//! batch widths and cache on/off — caching and batching change launch
//! counts, never results (`tests/tune_reuse.rs` asserts this; the
//! acceptance bench is `benches/tune_convergence.rs`).

mod genetic;
mod objective;
mod simplex;

pub use genetic::Genetic;
pub use objective::{CandidateEvaluator, Objective, ObjectiveKind};
pub use simplex::NelderMead;

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cache::{CacheStats, ReuseCache, ScopedCounters};
use crate::config::StudyConfig;
use crate::driver::{build_cache, make_inputs, prepare_candidates, StudyInputs};
use crate::sampling::{default_space, ParamSet, CANONICAL_ACTIVE};
use crate::{Error, Result};

/// Which optimizer drives the search.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TunerKind {
    /// Nelder-Mead downhill simplex with speculatively batched probes.
    Simplex,
    /// Genetic algorithm over grid-level genomes.
    Genetic,
}

impl TunerKind {
    pub fn name(&self) -> &'static str {
        match self {
            TunerKind::Simplex => "simplex",
            TunerKind::Genetic => "genetic",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "nm" | "simplex" | "nelder-mead" => Ok(TunerKind::Simplex),
            "ga" | "genetic" => Ok(TunerKind::Genetic),
            other => Err(Error::Config(format!("unknown tuner `{other}`"))),
        }
    }
}

/// Tuning-run knobs, orthogonal to the per-candidate [`StudyConfig`]
/// (which supplies tiles, seed, cache, batch width, workers — its
/// `method`/`sampler` are ignored by tuning).
#[derive(Clone, Debug, PartialEq)]
pub struct TuneOptions {
    pub method: TunerKind,
    /// Evaluation budget: the loop stops asking once this many
    /// candidates were proposed (generations are atomic, so the last
    /// one may overshoot by less than one generation).
    pub budget: usize,
    /// GA population size (the simplex ignores it).
    pub population: usize,
    /// Search over the first `k_active` parameters of the canonical
    /// MOAT-screen ranking ([`CANONICAL_ACTIVE`]); ignored when
    /// `active` names explicit indices.
    pub k_active: usize,
    /// Explicit active parameter indices (empty = canonical top-k).
    pub active: Vec<usize>,
    pub objective: ObjectiveKind,
    /// Cost-penalty weight of the objective (see [`Objective`]).
    pub cost_lambda: f64,
    /// Initial candidates draw their per-dimension grid fractions from
    /// this window of [0, 1] — `(0.0, 1.0)` spans each grid; a narrow
    /// window starts the search in a known region (e.g. away from the
    /// incumbent defaults).
    pub init_window: (f64, f64),
    /// GA per-gene mutation probability.
    pub mutation: f64,
    /// Ask the serving side to speculatively pre-execute this tuner's
    /// predicted next generation while the current one is being scored
    /// (`speculate=on`). Purely a cache-warming hint: the standalone
    /// loop ignores it, and a wrong prediction costs idle-worker time,
    /// never a changed result.
    pub speculate: bool,
}

impl Default for TuneOptions {
    fn default() -> Self {
        Self {
            method: TunerKind::Genetic,
            budget: 64,
            population: 12,
            k_active: 8,
            active: Vec::new(),
            objective: ObjectiveKind::Dice,
            cost_lambda: 0.0,
            init_window: (0.0, 1.0),
            mutation: 0.25,
            speculate: false,
        }
    }
}

impl TuneOptions {
    /// The active parameter indices this run searches over.
    pub fn active_params(&self) -> Vec<usize> {
        if self.active.is_empty() {
            CANONICAL_ACTIVE.iter().copied().take(self.k_active.clamp(1, 8)).collect()
        } else {
            self.active.clone()
        }
    }
}

/// An optimizer over parameter sets: propose a generation, learn its
/// scores, repeat. Scores are maximized. Implementations must be
/// deterministic in (construction seed, told scores) — the tuning
/// loop's bit-reproducibility rests on it.
pub trait Tuner {
    fn name(&self) -> &'static str;
    /// The next generation of candidates (empty = converged or budget
    /// exhausted). Every `ask` must be answered by one `tell` before
    /// the next `ask`.
    fn ask(&mut self) -> Vec<ParamSet>;
    /// Scores for the last asked generation, same order, higher better.
    fn tell(&mut self, scores: &[f64]);
    /// Predict the generation this tuner would ask next if the
    /// outstanding one scored `guessed_scores` — WITHOUT advancing any
    /// state. Used by speculative execution ([`crate::serve`]) to warm
    /// the cache while the real scores are still being computed; a
    /// prediction is a pure hint, so the default is "no prediction".
    fn speculate_next(&self, _guessed_scores: &[f64]) -> Vec<ParamSet> {
        Vec::new()
    }
}

/// Receiver of speculative-execution hints: [`run_tune_with_hook`]
/// offers each predicted next generation here *before* scoring the real
/// one, and the service's idle workers pre-execute the offered sets
/// through the normal single-flight cache path. Implementations must
/// treat offers as hints — dropping them is always correct.
pub trait SpeculationHook: Sync {
    fn offer(&self, candidates: &[ParamSet]);
}

/// Build the tuner a [`TuneOptions`] describes, seeded for determinism.
pub fn build_tuner(opts: &TuneOptions, seed: u64) -> Box<dyn Tuner> {
    let space = default_space();
    let active = opts.active_params();
    match opts.method {
        TunerKind::Genetic => Box::new(Genetic::new(space, active, opts, seed)),
        TunerKind::Simplex => Box::new(NelderMead::new(space, active, opts, seed)),
    }
}

/// One generation's progress row.
#[derive(Clone, Debug)]
pub struct GenerationReport {
    pub gen: usize,
    /// Candidates the tuner proposed.
    pub asked: usize,
    /// Of those, how many actually ran as studies...
    pub evaluated: usize,
    /// ...and how many the per-run memo table served.
    pub memo_hits: usize,
    /// Best score seen so far (cumulative).
    pub best_score: f64,
}

/// What a tuning run found.
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    pub method: TunerKind,
    /// The best candidate (full parameter set, on the Table-1 grids).
    pub best_params: ParamSet,
    pub best_score: f64,
    /// Best score of the *initial* generation — the convergence
    /// baseline the acceptance bench measures improvement against.
    pub initial_best_score: f64,
    pub history: Vec<GenerationReport>,
    /// Candidates proposed / actually executed / memo-served.
    pub asked: usize,
    pub evaluated: usize,
    pub memo_hits: usize,
    /// Backend launches paid / executions served by the shared cache.
    pub launches: u64,
    pub cached_tasks: u64,
    pub wall: Duration,
    /// Shared-cache counters at the end of the run (when attached).
    pub cache: Option<CacheStats>,
}

impl TuneOutcome {
    /// Did the search strictly improve on the best initial candidate?
    pub fn improved(&self) -> bool {
        self.best_score > self.initial_best_score
    }

    /// The compact summary serve job reports carry over the wire.
    pub fn summary(&self) -> TuneSummary {
        TuneSummary {
            method: self.method.name().to_string(),
            best_score: self.best_score,
            initial_best_score: self.initial_best_score,
            best_params: self.best_params.clone(),
            evaluated: self.evaluated as u64,
            memo_hits: self.memo_hits as u64,
            generations: self.history.len() as u64,
        }
    }
}

/// Compact tuning-run summary attached to serve job reports (in-process
/// and over the wire — `serve/protocol.rs` serializes it verbatim).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TuneSummary {
    pub method: String,
    pub best_score: f64,
    pub initial_best_score: f64,
    pub best_params: Vec<f64>,
    pub evaluated: u64,
    pub memo_hits: u64,
    pub generations: u64,
}

/// Run one tuning loop: ask generations, score each as one batched
/// study, tell the scores back, until the tuner converges or the budget
/// runs out. `cache`/`scope`/`inputs` follow the
/// [`crate::driver::run_pjrt_with_inputs_scoped`] contract — the
/// multi-tenant service passes its process-lifetime cache and the
/// tenant's counter scope here, so concurrent tuning runs share one
/// cache and bill separately.
pub fn run_tune(
    cfg: &StudyConfig,
    opts: &TuneOptions,
    cache: Option<Arc<ReuseCache>>,
    scope: Option<Arc<ScopedCounters>>,
    inputs: &StudyInputs,
) -> Result<TuneOutcome> {
    run_tune_with_hook(cfg, opts, cache, scope, inputs, None)
}

/// [`run_tune`] with a speculation hook: after each `ask` and *before*
/// the generation is scored, the tuner's predicted next generation
/// (assuming neutral scores — the prediction must not depend on results
/// that don't exist yet) is offered to `hook`. Whether and when the
/// hook executes the offer cannot affect this loop's results: the
/// prediction never feeds back into the tuner, and any overlap with the
/// real scoring resolves through the cache's single-flight claims.
pub fn run_tune_with_hook(
    cfg: &StudyConfig,
    opts: &TuneOptions,
    cache: Option<Arc<ReuseCache>>,
    scope: Option<Arc<ScopedCounters>>,
    inputs: &StudyInputs,
    hook: Option<&dyn SpeculationHook>,
) -> Result<TuneOutcome> {
    let start = Instant::now();
    let mut tuner = build_tuner(opts, cfg.seed);
    let objective = Objective::for_study(cfg, opts.objective, opts.cost_lambda);
    let mut ev = CandidateEvaluator::new(cfg, objective, cache.clone(), scope, inputs);

    let mut history: Vec<GenerationReport> = Vec::new();
    let mut best: Option<(f64, ParamSet)> = None;
    let mut initial_best = f64::NEG_INFINITY;
    let mut asked_total = 0usize;
    loop {
        if asked_total >= opts.budget {
            break;
        }
        let generation = tuner.ask();
        if generation.is_empty() {
            break;
        }
        if let Some(h) = hook {
            let predicted = tuner.speculate_next(&vec![0.0; generation.len()]);
            if !predicted.is_empty() {
                h.offer(&predicted);
            }
        }
        let (ev_before, memo_before) = (ev.evaluated, ev.memo_hits);
        let scores = ev.score_batch(&generation)?;
        for (set, &score) in generation.iter().zip(&scores) {
            if best.as_ref().is_none_or(|(b, _)| score > *b) {
                best = Some((score, set.clone()));
            }
        }
        if history.is_empty() {
            initial_best = scores.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        }
        asked_total += generation.len();
        history.push(GenerationReport {
            gen: history.len(),
            asked: generation.len(),
            evaluated: ev.evaluated - ev_before,
            memo_hits: ev.memo_hits - memo_before,
            best_score: best.as_ref().expect("scored at least one candidate").0,
        });
        tuner.tell(&scores);
    }
    let (best_score, best_params) =
        best.ok_or_else(|| Error::Config("tuning evaluated no candidates (budget 0?)".into()))?;
    Ok(TuneOutcome {
        method: opts.method,
        best_params,
        best_score,
        initial_best_score: initial_best,
        history,
        asked: asked_total,
        evaluated: ev.evaluated,
        memo_hits: ev.memo_hits,
        launches: ev.launches,
        cached_tasks: ev.cached_tasks,
        wall: start.elapsed(),
        cache: cache.map(|c| c.stats()),
    })
}

/// [`run_tune`] building its own cache (per `cfg.cache`) and study
/// inputs — the `tune` CLI mode's entry. Pays one engine load plus a
/// reference-chain run per tile before the loop starts.
pub fn run_tune_standalone(cfg: &StudyConfig, opts: &TuneOptions) -> Result<TuneOutcome> {
    let cache = build_cache(cfg);
    let probe = prepare_candidates(cfg, &[default_space().defaults()]);
    let inputs = make_inputs(cfg, &probe)?;
    run_tune(cfg, opts, cache, None, &inputs)
}
