//! The objective layer: what a candidate parameter set *scores*, and
//! the evaluator that produces those scores by running candidates as
//! real studies.
//!
//! One [`CandidateEvaluator`] lives for one tuning run. It batches every
//! generation it is handed into ONE multi-unit study
//! ([`crate::driver::prepare_candidates`] →
//! [`crate::driver::run_pjrt_with_inputs_scoped`]), so stage/task
//! merging and frontier batching stack sibling candidates into batched
//! kernel launches, and partial chain overlap between neighboring
//! candidates hits the shared [`crate::cache::ReuseCache`]. On top of
//! the chain-level cache it keeps a per-run **memo table** keyed by the
//! quantized 128-bit [`candidate_key`] of each parameter vector:
//! optimizer iterates that revisit a quantized point skip even the study
//! setup — the highest-frequency reuse event of Nelder-Mead and GA
//! searches over discrete parameter grids.

use std::collections::HashMap;
use std::sync::Arc;

use crate::cache::{candidate_key, Key, ReuseCache, ScopedCounters};
use crate::config::StudyConfig;
use crate::driver::{
    prepare_candidates, prune_plan_with_inputs, run_pjrt_with_inputs_scoped, study_workflow,
    StudyInputs,
};
use crate::sampling::{default_space, ParamSet};
use crate::simulate::{default_cost_model, CostModel};
use crate::workflow::WorkflowSpec;
use crate::{Error, Result};

/// Which mask-similarity metric the tuner maximizes (always against the
/// reference masks the study inputs carry — the workflow run with the
/// application-default parameters, paper §4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObjectiveKind {
    /// Dice coefficient of the final mask vs. the reference.
    Dice,
    /// Jaccard index of the final mask vs. the reference.
    Jaccard,
}

impl ObjectiveKind {
    pub fn name(&self) -> &'static str {
        match self {
            ObjectiveKind::Dice => "dice",
            ObjectiveKind::Jaccard => "jaccard",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "dice" => Ok(ObjectiveKind::Dice),
            "jaccard" | "iou" => Ok(ObjectiveKind::Jaccard),
            other => Err(Error::Config(format!("unknown objective `{other}`"))),
        }
    }
}

/// The scalar a tuner maximizes: a mask metric, optionally penalized by
/// the predicted execution cost of the candidate's task chain.
#[derive(Clone, Debug)]
pub struct Objective {
    pub kind: ObjectiveKind,
    /// Score = metric − `cost_lambda` × predicted chain cost (seconds,
    /// from a [`CostModel`] over the workflow's task path). 0 = pure
    /// accuracy. The model prices task *names*, so with the fixed paper
    /// workflow the penalty is a constant offset; it discriminates when
    /// candidates run different workflows (descriptor files) or when a
    /// measured, input-dependent model is supplied.
    pub cost_lambda: f64,
    chain_cost_secs: f64,
}

impl Objective {
    /// An objective pricing `workflow`'s full task chain with `model`.
    pub fn new(
        kind: ObjectiveKind,
        cost_lambda: f64,
        model: &CostModel,
        workflow: &WorkflowSpec,
    ) -> Self {
        let chain_cost_secs = workflow
            .stages
            .iter()
            .flat_map(|s| s.tasks.iter())
            .map(|t| model.cost_of(&t.name))
            .sum();
        Self { kind, cost_lambda: cost_lambda.max(0.0), chain_cost_secs }
    }

    /// [`Objective::new`] over the study's workflow and the default
    /// (Table-6) cost model — what the CLI and the serve job kind use.
    pub fn for_study(cfg: &StudyConfig, kind: ObjectiveKind, cost_lambda: f64) -> Self {
        let space = default_space();
        let workflow = study_workflow(cfg, &space);
        Self::new(kind, cost_lambda, &default_cost_model(), &workflow)
    }

    /// Score one candidate from its mean `(dice, jaccard)` pair. Higher
    /// is better.
    pub fn score(&self, dice: f64, jaccard: f64) -> f64 {
        let metric = match self.kind {
            ObjectiveKind::Dice => dice,
            ObjectiveKind::Jaccard => jaccard,
        };
        metric - self.cost_lambda * self.chain_cost_secs
    }

    /// The priced chain cost (seconds) the penalty multiplies.
    pub fn chain_cost_secs(&self) -> f64 {
        self.chain_cost_secs
    }
}

/// Scores candidate parameter sets by running them as studies (see the
/// module docs). Counters are public so callers (the tuning loop, the
/// reuse tests, the convergence bench) can assert on them.
pub struct CandidateEvaluator<'a> {
    cfg: &'a StudyConfig,
    objective: Objective,
    cache: Option<Arc<ReuseCache>>,
    scope: Option<Arc<ScopedCounters>>,
    inputs: &'a StudyInputs,
    memo: HashMap<Key, f64>,
    /// Quantization step of the memo keys — the attached cache's step,
    /// so memo identity and chain-key identity can never disagree.
    step: f64,
    /// Distinct candidates actually executed as studies.
    pub evaluated: usize,
    /// Requests served by the per-run memo table.
    pub memo_hits: usize,
    /// Backend launches paid across every executed generation.
    pub launches: u64,
    /// Task executions served from the shared reuse cache.
    pub cached_tasks: u64,
}

impl<'a> CandidateEvaluator<'a> {
    /// Build an evaluator over pre-built study inputs. `inputs` must
    /// come from the same artifacts/tile configuration as `cfg` (the
    /// usual [`crate::driver::make_inputs`] contract).
    pub fn new(
        cfg: &'a StudyConfig,
        objective: Objective,
        cache: Option<Arc<ReuseCache>>,
        scope: Option<Arc<ScopedCounters>>,
        inputs: &'a StudyInputs,
    ) -> Self {
        let step = cache.as_ref().map(|c| c.quantize_step()).unwrap_or(cfg.cache.quantize);
        Self {
            cfg,
            objective,
            cache,
            scope,
            inputs,
            memo: HashMap::new(),
            step,
            evaluated: 0,
            memo_hits: 0,
            launches: 0,
            cached_tasks: 0,
        }
    }

    /// The objective this evaluator scores with.
    pub fn objective(&self) -> &Objective {
        &self.objective
    }

    /// Score a generation: memo-served candidates (and within-batch
    /// duplicates) cost nothing; the remaining fresh candidates run as
    /// ONE batched study. Returns one score per requested set, in
    /// order. Scores are bit-deterministic for a fixed config: batch
    /// width and cache on/off change launch counts, never results.
    pub fn score_batch(&mut self, sets: &[ParamSet]) -> Result<Vec<f64>> {
        let keys: Vec<Key> = sets.iter().map(|s| candidate_key(s, self.step)).collect();
        let mut fresh: Vec<ParamSet> = Vec::new();
        let mut fresh_keys: Vec<Key> = Vec::new();
        for (set, key) in sets.iter().zip(&keys) {
            if !self.memo.contains_key(key) && !fresh_keys.contains(key) {
                fresh.push(set.clone());
                fresh_keys.push(*key);
            }
        }
        self.memo_hits += sets.len() - fresh.len();
        if !fresh.is_empty() {
            let prepared = prepare_candidates(self.cfg, &fresh);
            let mut plan = prepared.plan(self.cfg);
            if let Some(cache) = &self.cache {
                // planning-time probe: LPT orders by work that will run
                let _ = prune_plan_with_inputs(&prepared, &mut plan, cache, self.inputs);
            }
            let outcome = run_pjrt_with_inputs_scoped(
                self.cfg,
                &prepared,
                &plan,
                self.cache.clone(),
                self.scope.clone(),
                self.inputs,
            )?;
            self.launches += outcome.timer.launches();
            self.cached_tasks += outcome.timer.cached_served();
            let tiles = self.cfg.tiles.max(1);
            for (i, key) in fresh_keys.iter().enumerate() {
                let per_tile = &outcome.metrics[i * tiles..(i + 1) * tiles];
                let dice = per_tile.iter().map(|m| m[0] as f64).sum::<f64>() / tiles as f64;
                let jaccard = per_tile.iter().map(|m| m[1] as f64).sum::<f64>() / tiles as f64;
                self.memo.insert(*key, self.objective.score(dice, jaccard));
            }
            self.evaluated += fresh.len();
        }
        Ok(keys.iter().map(|k| self.memo[k]).collect())
    }
}
