//! Nelder-Mead (downhill simplex) tuner with **speculatively batched
//! probes**: classic NM evaluates one or two points per iteration,
//! which wastes the batched study evaluator; this variant asks for the
//! reflection, expansion and both contraction points of an iteration in
//! ONE generation, then applies the standard acceptance rules to the
//! four scores. The one or two points the rules discard cost almost
//! nothing in practice — NM probes cluster around the centroid, so
//! their quantized task chains overlap the accepted point's in the
//! shared cache, and re-probing a grid cell a previous iteration
//! visited is a pure memo hit.
//!
//! The simplex lives in the continuous unit cube over the *active*
//! parameters; every probe snaps to the discrete Table-1 grid before
//! evaluation (the evaluator additionally quantizes with the cache
//! step), so the search revisits quantized points constantly — the
//! run-time SA/tuning reuse profile the related work measures.

use crate::data::SplitMix64;
use crate::sampling::{ParamSet, ParamSpace};

use super::{TuneOptions, Tuner};

/// A simplex vertex in the unit cube over the active dimensions.
type Point = Vec<f64>;

#[derive(Clone)]
enum Phase {
    /// Nothing asked yet.
    Start,
    /// The initial `k + 1` vertices are out for evaluation.
    AwaitInit { pts: Vec<Point> },
    /// Simplex scored and sorted; the next ask probes a step.
    Ready,
    /// The four speculative probes of one iteration are out.
    AwaitProbe { pts: [Point; 4] },
    /// Every probe failed: the next ask shrinks toward the best vertex.
    NeedShrink,
    /// The shrunk replacement vertices are out.
    AwaitShrink { pts: Vec<Point> },
    /// Converged (degenerate simplex) or budget exhausted.
    Done,
}

/// The Nelder-Mead tuner (see the module docs). `Clone` exists for
/// [`Tuner::speculate_next`]: predicting the next generation runs
/// tell → ask on a throwaway copy, leaving the real state untouched.
#[derive(Clone)]
pub struct NelderMead {
    space: ParamSpace,
    active: Vec<usize>,
    defaults: ParamSet,
    budget: usize,
    asked_total: usize,
    init_window: (f64, f64),
    rng: SplitMix64,
    /// Vertices with scores, kept sorted best-first between phases.
    simplex: Vec<(Point, f64)>,
    phase: Phase,
}

impl NelderMead {
    /// A simplex search over `active` parameter indices of `space`;
    /// inactive parameters stay at the space defaults.
    pub fn new(space: ParamSpace, active: Vec<usize>, opts: &TuneOptions, seed: u64) -> Self {
        assert!(!active.is_empty(), "Nelder-Mead needs at least one active parameter");
        let defaults = space.defaults();
        Self {
            space,
            active,
            defaults,
            budget: opts.budget.max(1),
            asked_total: 0,
            init_window: opts.init_window,
            rng: SplitMix64::new(seed ^ 0x6e6d), // domain-separated from the samplers
            simplex: Vec::new(),
            phase: Phase::Start,
        }
    }

    fn dim(&self) -> usize {
        self.active.len()
    }

    /// Snap a unit-cube point onto the full (grid-valued) parameter set.
    fn point_params(&self, x: &[f64]) -> ParamSet {
        let mut params = self.defaults.clone();
        for (d, &f) in x.iter().enumerate() {
            let p = self.active[d];
            let def = &self.space.params[p];
            params[p] = def.value_at(def.level_of_fraction(f));
        }
        params
    }

    fn ask_points(&mut self, pts: &[Point]) -> Vec<ParamSet> {
        self.asked_total += pts.len();
        pts.iter().map(|x| self.point_params(x)).collect()
    }

    /// Centroid of every vertex but the worst (simplex is sorted).
    fn centroid(&self) -> Point {
        let k = self.dim();
        let mut c = vec![0.0; k];
        for (x, _) in &self.simplex[..self.simplex.len() - 1] {
            for (d, v) in x.iter().enumerate() {
                c[d] += v;
            }
        }
        for v in &mut c {
            *v /= (self.simplex.len() - 1) as f64;
        }
        c
    }

    /// `c + t·(c − w)` clamped into the unit cube.
    fn toward(c: &[f64], w: &[f64], t: f64) -> Point {
        c.iter().zip(w).map(|(&cv, &wv)| (cv + t * (cv - wv)).clamp(0.0, 1.0)).collect()
    }

    fn sort_simplex(&mut self) {
        self.simplex.sort_by(|a, b| b.1.total_cmp(&a.1)); // best first
    }

    /// The simplex collapsed to (numerically) one point: further probes
    /// cannot move, so the search is done.
    fn degenerate(&self) -> bool {
        let (best, _) = &self.simplex[0];
        self.simplex[1..]
            .iter()
            .all(|(x, _)| x.iter().zip(best).all(|(a, b)| (a - b).abs() < 1e-9))
    }
}

impl Tuner for NelderMead {
    fn name(&self) -> &'static str {
        "simplex"
    }

    fn ask(&mut self) -> Vec<ParamSet> {
        if self.asked_total >= self.budget {
            self.phase = Phase::Done;
            return Vec::new();
        }
        // take the phase out so the arms can freely mutate `self`
        let phase = std::mem::replace(&mut self.phase, Phase::Done);
        match phase {
            Phase::Start => {
                // x0 random inside the init window; vertex i offsets
                // dimension i−1 by 0.3, reflected back into the cube
                let (lo, hi) = self.init_window;
                let mut x0 = Vec::with_capacity(self.dim());
                for _ in 0..self.dim() {
                    x0.push(self.rng.uniform(lo, hi));
                }
                let mut pts = vec![x0.clone()];
                for d in 0..self.dim() {
                    let mut x = x0.clone();
                    if x[d] + 0.3 <= 1.0 {
                        x[d] += 0.3;
                    } else {
                        x[d] -= 0.3;
                    }
                    pts.push(x);
                }
                let sets = self.ask_points(&pts);
                self.phase = Phase::AwaitInit { pts };
                sets
            }
            Phase::Ready => {
                if self.degenerate() {
                    return Vec::new(); // phase stays Done: converged
                }
                let worst = self.simplex.last().expect("simplex populated").0.clone();
                let c = self.centroid();
                let pts = [
                    Self::toward(&c, &worst, 1.0),  // reflection
                    Self::toward(&c, &worst, 2.0),  // expansion
                    Self::toward(&c, &worst, 0.5),  // outer contraction
                    Self::toward(&c, &worst, -0.5), // inner contraction
                ];
                let sets = self.ask_points(&pts);
                self.phase = Phase::AwaitProbe { pts };
                sets
            }
            Phase::NeedShrink => {
                let best = self.simplex[0].0.clone();
                let pts: Vec<Point> = self.simplex[1..]
                    .iter()
                    .map(|(x, _)| x.iter().zip(&best).map(|(&v, &b)| b + 0.5 * (v - b)).collect())
                    .collect();
                let sets = self.ask_points(&pts);
                self.phase = Phase::AwaitShrink { pts };
                sets
            }
            waiting => {
                // Done, or an Await* phase still owed a tell(): nothing
                // new to ask
                self.phase = waiting;
                Vec::new()
            }
        }
    }

    fn tell(&mut self, scores: &[f64]) {
        match std::mem::replace(&mut self.phase, Phase::Ready) {
            Phase::AwaitInit { pts } => {
                assert_eq!(scores.len(), pts.len());
                self.simplex = pts.into_iter().zip(scores.iter().copied()).collect();
                self.sort_simplex();
            }
            Phase::AwaitProbe { pts } => {
                assert_eq!(scores.len(), 4);
                let [reflect, expand, outer, inner] = pts;
                let (fr, fe, fo, fi) = (scores[0], scores[1], scores[2], scores[3]);
                let f_best = self.simplex[0].1;
                let f_second_worst = self.simplex[self.simplex.len() - 2].1;
                let f_worst = self.simplex[self.simplex.len() - 1].1;
                let worst = self.simplex.len() - 1;
                if fr > f_best {
                    // the reflection leads: take the expansion if it
                    // leads further
                    if fe > fr {
                        self.simplex[worst] = (expand, fe);
                    } else {
                        self.simplex[worst] = (reflect, fr);
                    }
                } else if fr > f_second_worst {
                    self.simplex[worst] = (reflect, fr);
                } else {
                    let (cx, fc) = if fo >= fi { (outer, fo) } else { (inner, fi) };
                    if fc > f_worst {
                        self.simplex[worst] = (cx, fc);
                    } else {
                        self.phase = Phase::NeedShrink;
                    }
                }
                self.sort_simplex();
            }
            Phase::AwaitShrink { pts } => {
                assert_eq!(scores.len(), pts.len());
                for (i, (x, s)) in pts.into_iter().zip(scores.iter().copied()).enumerate() {
                    self.simplex[i + 1] = (x, s);
                }
                self.sort_simplex();
            }
            other => {
                self.phase = other;
                panic!("tell() without an outstanding ask");
            }
        }
    }

    fn speculate_next(&self, guessed_scores: &[f64]) -> Vec<ParamSet> {
        let outstanding = match &self.phase {
            Phase::AwaitInit { pts } => pts.len(),
            Phase::AwaitProbe { .. } => 4,
            Phase::AwaitShrink { pts } => pts.len(),
            _ => return Vec::new(),
        };
        if guessed_scores.len() != outstanding {
            return Vec::new();
        }
        let mut copy = self.clone();
        copy.tell(guessed_scores);
        copy.ask()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::default_space;
    use crate::tune::TunerKind;

    fn opts(budget: usize) -> TuneOptions {
        TuneOptions { method: TunerKind::Simplex, budget, ..TuneOptions::default() }
    }

    /// Drive the tuner on a smooth concave surrogate (peak at the
    /// defaults) and return (all asked sets, best score seen).
    fn drive(mut nm: NelderMead, space: &ParamSpace) -> (Vec<Vec<ParamSet>>, f64) {
        let defaults = space.defaults();
        let mut best = f64::NEG_INFINITY;
        let mut gens = Vec::new();
        loop {
            let generation = nm.ask();
            if generation.is_empty() {
                break;
            }
            let scores: Vec<f64> = generation
                .iter()
                .map(|s| -s.iter().zip(&defaults).map(|(a, b)| (a - b).abs()).sum::<f64>())
                .collect();
            best = scores.iter().copied().fold(best, f64::max);
            gens.push(generation);
            nm.tell(&scores);
        }
        (gens, best)
    }

    #[test]
    fn phases_ask_expected_batch_sizes_and_converge_toward_the_peak() {
        let space = default_space();
        let nm = NelderMead::new(space.clone(), vec![5, 6], &opts(40), 11);
        let (gens, best) = drive(nm, &space);
        assert_eq!(gens[0].len(), 3, "k + 1 initial vertices for k = 2");
        assert!(gens[1..].iter().all(|g| g.len() == 4 || g.len() == 2), "probe or shrink");
        let init_best = {
            let defaults = space.defaults();
            gens[0]
                .iter()
                .map(|s| -s.iter().zip(&defaults).map(|(a, b)| (a - b).abs()).sum::<f64>())
                .fold(f64::NEG_INFINITY, f64::max)
        };
        assert!(best >= init_best, "the simplex never loses its best vertex");
    }

    #[test]
    fn fixed_seed_trajectories_are_identical() {
        let space = default_space();
        let a = drive(NelderMead::new(space.clone(), vec![5, 6, 7], &opts(30), 5), &space);
        let b = drive(NelderMead::new(space.clone(), vec![5, 6, 7], &opts(30), 5), &space);
        assert_eq!(a.0, b.0);
        // seeds matter: some nearby seed starts the simplex elsewhere
        // (any single seed could snap onto the same grid cell)
        let differs = (6..16).any(|seed| {
            let c = drive(NelderMead::new(space.clone(), vec![5, 6, 7], &opts(30), seed), &space);
            c.0 != a.0
        });
        assert!(differs, "ten nearby seeds cannot all reproduce seed 5's trajectory");
    }

    #[test]
    fn speculate_next_predicts_without_advancing_state() {
        let space = default_space();
        let mut nm = NelderMead::new(space.clone(), vec![5, 6], &opts(40), 11);
        let g1 = nm.ask();
        let guess = vec![0.0; g1.len()];
        let predicted = nm.speculate_next(&guess);
        assert_eq!(predicted, nm.speculate_next(&guess), "speculation is pure");
        nm.tell(&guess);
        assert_eq!(nm.ask(), predicted, "telling the guess realizes the prediction");
        // a guess of the wrong arity is refused, not mis-applied
        assert!(nm.speculate_next(&[0.0]).is_empty());
    }

    #[test]
    fn candidates_stay_on_grid() {
        let space = default_space();
        let mut nm = NelderMead::new(space.clone(), vec![5], &opts(10), 3);
        for set in nm.ask() {
            space.validate(&set).expect("snapped to the grid");
        }
    }
}
