//! Minimal in-crate bench harness (criterion is not vendored in this
//! environment). Provides wall-clock measurement with warmup plus
//! aligned table printing for the paper-style reports every bench emits.

use std::time::{Duration, Instant};

/// Measure `f`'s wall time: one warmup call, then the mean over `iters`
/// measured calls.
pub fn time_mean<F: FnMut()>(iters: usize, mut f: F) -> Duration {
    f(); // warmup
    let start = Instant::now();
    for _ in 0..iters.max(1) {
        f();
    }
    start.elapsed() / iters.max(1) as u32
}

/// Measure a single call.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let start = Instant::now();
    let v = f();
    (v, start.elapsed())
}

/// Pretty seconds.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0} s")
    } else if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// An aligned plain-text table (the shape every paper table/figure bench
/// prints).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut width = vec![0usize; cols];
        for (c, h) in self.headers.iter().enumerate() {
            width[c] = h.len();
        }
        for row in &self.rows {
            for (c, cell) in row.iter().enumerate() {
                width[c] = width[c].max(cell.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], width: &[usize], out: &mut String| {
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    out.push_str("  ");
                }
                out.push_str(&format!("{cell:>w$}", w = width[c]));
            }
            out.push('\n');
        };
        line(&self.headers, &width, &mut out);
        let total: usize = width.iter().sum::<usize>() + 2 * (cols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(row, &width, &mut out);
        }
        out
    }

    pub fn print(&self, title: &str) {
        println!("\n== {title} ==");
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "123456".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[2].ends_with("1"));
        assert!(lines[3].ends_with("123456"));
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() <= w + 2));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn timing_smoke() {
        let d = time_mean(3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(d.as_nanos() > 0);
        let (v, d2) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d2.as_nanos() > 0);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(123.4), "123 s");
        assert_eq!(fmt_secs(2.5), "2.50 s");
        assert_eq!(fmt_secs(0.0025), "2.50 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.5 µs");
    }
}
