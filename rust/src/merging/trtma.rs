//! Task-Balanced Reuse-Tree Merging Algorithm (TRTMA) — paper §3.3.4 —
//! and its cost-balanced variant (the paper's §5 future work).
//!
//! RTMA balances buckets *stage-wise*; different reuse patterns then
//! leave buckets with very different task counts, which costs parallel
//! efficiency once the buckets-per-worker ratio drops (paper Figs 22/23).
//! TRTMA instead targets `MaxBuckets` buckets and balances them
//! *task-wise* in three steps:
//!
//! 1. **Full-Merge** — walk the reuse tree top-down to the first level
//!    with at least `MaxBuckets` nodes; each node's leaves form a bucket.
//! 2. **Fold-Merge** — while there are more than `MaxBuckets` buckets,
//!    fold the cost-sorted bucket line at the pivot: the cheapest
//!    overflow buckets merge into the cheapest surviving ones,
//!    mitigating the imbalance the merge creates.
//! 3. **Balance** — repeatedly move a reuse-subtree from the costliest
//!    bucket (`bigRT`) to the cheapest (`smallRT`) while it reduces the
//!    task imbalance *and* the makespan ("false improvements" that lower
//!    imbalance without lowering the maximum cost are rejected).
//!    `SingleBalance` searches bigRT's subtree bottom-up with the paper's
//!    two prunings: single-child descent and unique-sibling skipping
//!    (siblings with equal task cost and leaf count are interchangeable).
//!
//! All three steps run over a generic bucket-cost function. With the
//! unit cost (every task weighs 1) this is the paper's TRTMA; with
//! per-level costs from the measured Table-6 model
//! ([`trtma_merge_weighted`]) it is the **cost-balanced TRTMA** the
//! paper's conclusion proposes: buckets balanced by estimated seconds
//! instead of task count, removing the Fig.-24 topology imbalance.

use std::collections::HashSet;

use super::plan::{unique_tasks, weighted_tasks, Bucket, MergeStage};
use super::reuse_tree::ReuseTree;

/// TRTMA configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TrtmaOptions {
    /// Target number of buckets (paper: 3× the worker-process count).
    pub max_buckets: usize,
    /// smallRT selection: `false` = last bucket (paper's default),
    /// `true` = among the cheapest buckets, the one with the highest
    /// reuse with bigRT (paper §3.3.4 "Discussion": negligible gain at
    /// O(n) extra cost — kept for the ablation bench).
    pub smallrt_best_reuse: bool,
}

impl TrtmaOptions {
    pub fn new(max_buckets: usize) -> Self {
        Self { max_buckets, smallrt_best_reuse: false }
    }
}

/// Run the TRTMA bucketing with the paper's unit task cost.
pub fn trtma_merge(stages: &[MergeStage], opts: TrtmaOptions) -> Vec<Bucket> {
    let cost = |members: &[usize]| unique_tasks(stages, members) as f64;
    trtma_with_cost(stages, opts, &cost)
}

/// Cost-balanced TRTMA (paper §5 future work): buckets balanced by the
/// summed *cost* of their unique tasks, with `level_costs[l]` the
/// estimated cost of the stage's task at level `l` (e.g. from the
/// measured Table-6 model). With uniform costs this equals
/// [`trtma_merge`].
pub fn trtma_merge_weighted(
    stages: &[MergeStage],
    opts: TrtmaOptions,
    level_costs: &[f64],
) -> Vec<Bucket> {
    let cost = |members: &[usize]| weighted_tasks(stages, members, level_costs);
    trtma_with_cost(stages, opts, &cost)
}

fn trtma_with_cost(
    stages: &[MergeStage],
    opts: TrtmaOptions,
    cost: &dyn Fn(&[usize]) -> f64,
) -> Vec<Bucket> {
    assert!(opts.max_buckets >= 1);
    if stages.is_empty() {
        return Vec::new();
    }
    let t = ReuseTree::build(stages);
    let mut buckets = full_merge(&t, opts.max_buckets);
    fold_merge(&mut buckets, opts.max_buckets, cost);
    balance(&t, &mut buckets, opts, cost);
    buckets.retain(|b| !b.is_empty());
    buckets
}

/// Step 1: first tree level with >= max_buckets nodes; the frontier
/// nodes' leaf sets are the initial buckets.
fn full_merge(t: &ReuseTree, max_buckets: usize) -> Vec<Bucket> {
    let mut frontier: Vec<usize> = t.nodes[t.root].children.clone();
    loop {
        if frontier.len() >= max_buckets {
            break;
        }
        // expand one level (leaves stay as they are)
        let mut next = Vec::with_capacity(frontier.len() * 2);
        let mut expanded = false;
        for &v in &frontier {
            if t.nodes[v].children.is_empty() {
                next.push(v);
            } else {
                next.extend(t.nodes[v].children.iter().copied());
                expanded = true;
            }
        }
        frontier = next;
        if !expanded {
            break; // reached the leaves everywhere
        }
    }
    frontier.into_iter().map(|v| Bucket::of(t.leaves_under(v))).collect()
}

/// Step 2: fold the cost-sorted bucket line at the MaxBuckets pivot
/// (paper Fig. 14) until at most max_buckets buckets remain.
fn fold_merge(buckets: &mut Vec<Bucket>, max_buckets: usize, cost: &dyn Fn(&[usize]) -> f64) {
    while buckets.len() > max_buckets {
        buckets.sort_by(|a, b| {
            cost(&b.members).partial_cmp(&cost(&a.members)).unwrap_or(std::cmp::Ordering::Equal)
        });
        let overflow = (buckets.len() - max_buckets).min(max_buckets);
        let folded: Vec<Bucket> = buckets.drain(buckets.len() - overflow..).collect();
        for (j, f) in folded.into_iter().enumerate() {
            // fold pivot: overflow bucket j lands on bucket Mb-1-j
            let target = max_buckets - 1 - j;
            buckets[target].members.extend(f.members);
        }
    }
}

/// Step 3: the Balance loop (Algorithm 5). Bucket costs are computed
/// once and then maintained incrementally — only the two buckets an
/// improvement touches are re-priced (EXPERIMENTS.md §Perf change 2).
fn balance(
    t: &ReuseTree,
    buckets: &mut Vec<Bucket>,
    opts: TrtmaOptions,
    cost: &dyn Fn(&[usize]) -> f64,
) {
    if buckets.len() < 2 {
        return;
    }
    let mut costs: Vec<f64> = buckets.iter().map(|b| cost(&b.members)).collect();
    loop {
        // cost-sorted views: index of the costliest and the smallRT pick
        let big_idx = (0..buckets.len())
            .max_by(|&a, &b| costs[a].partial_cmp(&costs[b]).unwrap())
            .unwrap();
        let big_cost = costs[big_idx];
        let small_idx = select_small_cached(buckets, &costs, big_idx, opts, cost);
        let small_cost = costs[small_idx];
        if big_cost <= small_cost {
            return;
        }
        let imbal = big_cost - small_cost;
        let imp = single_balance(
            t,
            &buckets[big_idx].members,
            &buckets[small_idx].members,
            imbal,
            cost,
        );
        let Some(imp) = imp else { return };
        let new_big: Vec<usize> =
            buckets[big_idx].members.iter().copied().filter(|m| !imp.contains(m)).collect();
        let mut new_small = buckets[small_idx].members.clone();
        new_small.extend(imp.iter().copied());
        let c_big = cost(&new_big);
        let c_small = cost(&new_small);
        if c_big.max(c_small) < big_cost {
            buckets[big_idx].members = new_big;
            buckets[small_idx].members = new_small;
            costs[big_idx] = c_big;
            costs[small_idx] = c_small;
        } else {
            return; // false improvement — would not reduce the makespan
        }
    }
}

/// smallRT selection strategy over cached costs.
fn select_small_cached(
    buckets: &[Bucket],
    costs: &[f64],
    big_idx: usize,
    opts: TrtmaOptions,
    cost: &dyn Fn(&[usize]) -> f64,
) -> usize {
    let min_idx = (0..buckets.len())
        .filter(|&i| i != big_idx)
        .min_by(|&a, &b| costs[a].partial_cmp(&costs[b]).unwrap())
        .expect("at least two buckets");
    if !opts.smallrt_best_reuse {
        return min_idx;
    }
    // among the buckets with the minimum cost, pick the one with the
    // highest reuse with bigRT
    let min_cost = costs[min_idx];
    let big = &buckets[big_idx].members;
    let big_cost = costs[big_idx];
    let mut best = min_idx;
    let mut best_reuse = f64::NEG_INFINITY;
    for (i, b) in buckets.iter().enumerate() {
        if i == big_idx || costs[i] != min_cost {
            continue;
        }
        let mut joint = big.clone();
        joint.extend(b.members.iter().copied());
        let reuse = big_cost + min_cost - cost(&joint);
        if reuse > best_reuse {
            best_reuse = reuse;
            best = i;
        }
    }
    best
}

/// Algorithm 4: search bigRT's reuse-subtree (restricted to its members)
/// for the leaf set whose move to smallRT minimizes the cost imbalance.
fn single_balance(
    t: &ReuseTree,
    big: &[usize],
    small: &[usize],
    imbal: f64,
    cost: &dyn Fn(&[usize]) -> f64,
) -> Option<Vec<usize>> {
    let big_set: HashSet<usize> = big.iter().copied().collect();
    let mut best: Option<Vec<usize>> = None;
    let mut best_imbal = imbal;
    search(t, t.root, &big_set, big, small, &mut best, &mut best_imbal, cost);
    best
}

/// Leaves of `node` that belong to bigRT.
fn big_leaves(t: &ReuseTree, node: usize, big_set: &HashSet<usize>) -> Vec<usize> {
    t.leaves_under(node).into_iter().filter(|s| big_set.contains(s)).collect()
}

#[allow(clippy::too_many_arguments)]
fn search(
    t: &ReuseTree,
    node: usize,
    big_set: &HashSet<usize>,
    big: &[usize],
    small: &[usize],
    best: &mut Option<Vec<usize>>,
    best_imbal: &mut f64,
    cost: &dyn Fn(&[usize]) -> f64,
) {
    // children with at least one bigRT leaf
    let mut cur = node;
    let populated = |t: &ReuseTree, v: usize, bs: &HashSet<usize>| -> Vec<usize> {
        t.nodes[v]
            .children
            .iter()
            .copied()
            .filter(|&c| !big_leaves(t, c, bs).is_empty())
            .collect()
    };
    // optimization (i): single-child pruning — descend chains, the
    // improvement sets are identical
    let mut children = populated(t, cur, big_set);
    while children.len() == 1 && !t.nodes[children[0]].children.is_empty() {
        cur = children[0];
        children = populated(t, cur, big_set);
    }

    // optimization (ii): unique-sibling selection — siblings with equal
    // (task cost, leaf count) are interchangeable improvements
    let mut seen: HashSet<(u64, usize)> = HashSet::new();
    let mut unique_children = Vec::new();
    for &c in &children {
        // recurse first: finer-grain improvements are balanced earlier
        search(t, c, big_set, big, small, best, best_imbal, cost);
        let leaves = big_leaves(t, c, big_set);
        let key = (cost(&leaves).to_bits(), leaves.len());
        if seen.insert(key) {
            unique_children.push(c);
        }
    }

    for c in unique_children {
        let imp = big_leaves(t, c, big_set);
        if imp.is_empty() || imp.len() >= big.len() {
            continue; // must move a proper, non-empty subset
        }
        let new_big: Vec<usize> = big.iter().copied().filter(|m| !imp.contains(m)).collect();
        let mut new_small = small.to_vec();
        new_small.extend(imp.iter().copied());
        let a = cost(&new_big);
        let b = cost(&new_small);
        let cur_imbal = (a - b).abs();
        if cur_imbal < *best_imbal {
            *best_imbal = cur_imbal;
            *best = Some(imp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merging::plan::{assert_partition, mk_stages, reuse_fraction, stats_for};
    use crate::merging::rtma_merge;

    fn costs(stages: &[MergeStage], buckets: &[Bucket]) -> Vec<usize> {
        let mut c: Vec<usize> =
            buckets.iter().map(|b| unique_tasks(stages, &b.members)).collect();
        c.sort();
        c
    }

    #[test]
    fn produces_at_most_max_buckets() {
        let stages = mk_stages(&[
            &[1, 10],
            &[1, 11],
            &[1, 12],
            &[2, 20],
            &[2, 21],
            &[3, 30],
            &[3, 31],
            &[4, 40],
        ]);
        for mb in 1..=8 {
            let buckets = trtma_merge(&stages, TrtmaOptions::new(mb));
            assert_partition(stages.len(), &buckets);
            assert!(buckets.len() <= mb.max(stages.len()), "mb={mb}: {buckets:?}");
            if mb <= 4 {
                assert!(buckets.len() <= mb, "mb={mb} got {}", buckets.len());
            }
        }
    }

    #[test]
    fn fig12_exact_division() {
        // Fig. 12: MaxBuckets = 3 and the level-2 branches divide the
        // stages exactly: 3 buckets come straight from Full-Merge.
        let stages = mk_stages(&[
            &[1, 10, 100],
            &[1, 10, 101],
            &[1, 11, 102],
            &[2, 20, 103],
            &[2, 20, 104],
        ]);
        let buckets = trtma_merge(&stages, TrtmaOptions::new(3));
        assert_partition(stages.len(), &buckets);
        assert_eq!(buckets.len(), 3);
    }

    #[test]
    fn balance_reduces_makespan_vs_rtma_like_split() {
        // one hot subtree and several tiny ones: stage-wise bucketing
        // leaves a heavy bucket; TRTMA must shave its cost down
        let mut paths: Vec<Vec<u64>> = Vec::new();
        for i in 0..12u64 {
            paths.push(vec![1, 10 + i, 100 + i]); // big family: shares task 1
        }
        paths.push(vec![2, 50, 200]);
        paths.push(vec![3, 60, 300]);
        let stages: Vec<MergeStage> =
            paths.into_iter().enumerate().map(|(i, p)| MergeStage::new(i, p)).collect();
        let buckets = trtma_merge(&stages, TrtmaOptions::new(3));
        assert_partition(stages.len(), &buckets);
        assert_eq!(buckets.len(), 3);
        let c = costs(&stages, &buckets);
        // makespan must beat the unbalanced split {family}, {x}, {y} =
        // cost 25 vs 3 vs 3
        assert!(*c.last().unwrap() < 25, "balanced makespan: {c:?}");
    }

    #[test]
    fn trtma_never_exceeds_rtma_makespan_when_bucket_counts_match() {
        // paper claim: TRTMA behaves like RTMA when parallelism is ample,
        // and fixes the imbalance when it is not
        use crate::data::SplitMix64;
        let mut rng = SplitMix64::new(5);
        let mut paths = Vec::new();
        for _ in 0..40 {
            let a = rng.uniform_usize(0, 4) as u64;
            let b = rng.uniform_usize(0, 4) as u64;
            paths.push(vec![a, a * 10 + b, rng.next_u64() % 11]);
        }
        let stages: Vec<MergeStage> =
            paths.into_iter().enumerate().map(|(i, p)| MergeStage::new(i, p)).collect();
        let rt = rtma_merge(&stages, 10);
        let tb = trtma_merge(&stages, TrtmaOptions::new(rt.len()));
        let rt_mksp = *costs(&stages, &rt).last().unwrap();
        let tb_mksp = *costs(&stages, &tb).last().unwrap();
        assert!(
            tb_mksp <= rt_mksp,
            "task-balanced makespan {tb_mksp} must not exceed rtma {rt_mksp}"
        );
    }

    #[test]
    fn fig15_balance_walkthrough() {
        // Fig. 15: buckets of costs 8, 9, 5 over a shared-prefix tree;
        // balancing moves one leaf from the cost-9 bucket to the cost-5
        // bucket giving 8, 8, 8.
        // Model: family A with 6 leaves + deep spine (cost 8 as bucket),
        // family B with 6 leaves (cost 9), family C small (cost 5).
        // We approximate with three families whose costs differ and
        // verify the balance step equalizes within one task.
        let mut paths: Vec<Vec<u64>> = Vec::new();
        for i in 0..6u64 {
            paths.push(vec![1, 1, 10 + i]); // A: 2 shared + 6 = cost 8
        }
        for i in 0..7u64 {
            paths.push(vec![2, 2, 20 + i]); // B: 2 shared + 7 = cost 9
        }
        for i in 0..3u64 {
            paths.push(vec![3, 3, 30 + i]); // C: 2 shared + 3 = cost 5
        }
        let stages: Vec<MergeStage> =
            paths.into_iter().enumerate().map(|(i, p)| MergeStage::new(i, p)).collect();
        let buckets = trtma_merge(&stages, TrtmaOptions::new(3));
        assert_partition(stages.len(), &buckets);
        let c = costs(&stages, &buckets);
        assert!(*c.last().unwrap() <= 8, "makespan balanced to <= 8: {c:?}");
    }

    #[test]
    fn false_improvement_rejected() {
        // paper §3.3.4: an improvement that reduces the imbalance but
        // not the makespan is "false" and must not be applied.
        // big = fam1 {(1,a,x1..x3),(1,b,y1)}: cost 7; small = fam2
        // {(2,c,z1..z2)}: cost 4; imbalance 3. Moving x3 gives costs
        // (6, 7): imbalance 1 — better — but the makespan stays 7, so
        // the buckets must stay (7, 4).
        let stages = mk_stages(&[
            &[1, 10, 100],
            &[1, 10, 101],
            &[1, 10, 102],
            &[1, 11, 103],
            &[2, 20, 200],
            &[2, 20, 201],
        ]);
        let buckets = trtma_merge(&stages, TrtmaOptions::new(2));
        assert_partition(stages.len(), &buckets);
        let c = costs(&stages, &buckets);
        assert_eq!(c, vec![4, 7], "no false improvement applied: {c:?}");
    }

    #[test]
    fn single_bucket_requested() {
        let stages = mk_stages(&[&[1, 2], &[1, 3], &[4, 5]]);
        let buckets = trtma_merge(&stages, TrtmaOptions::new(1));
        assert_partition(stages.len(), &buckets);
        assert_eq!(buckets.len(), 1);
        let st = stats_for(&stages, &buckets);
        assert_eq!(st.tasks_merged, 5);
    }

    #[test]
    fn reuse_survives_balancing() {
        use crate::data::SplitMix64;
        let mut rng = SplitMix64::new(31);
        let mut paths = Vec::new();
        for _ in 0..80 {
            let a = rng.uniform_usize(0, 6) as u64;
            paths.push(vec![a, a * 7 + rng.next_u64() % 3, rng.next_u64() % 13]);
        }
        let stages: Vec<MergeStage> =
            paths.into_iter().enumerate().map(|(i, p)| MergeStage::new(i, p)).collect();
        // paper: last-bucket selection reaches ~95% of the reuse of
        // RTMA with MaxBucketSize = n
        let all: Vec<usize> = (0..stages.len()).collect();
        let max_reuse = 1.0
            - crate::merging::reuse_tree::ReuseTree::build(&stages).unique_task_count() as f64
                / stages.iter().map(|s| s.path.len()).sum::<usize>() as f64;
        let _ = all;
        let buckets = trtma_merge(&stages, TrtmaOptions::new(6));
        let r = reuse_fraction(&stages, &buckets);
        assert!(
            r >= 0.6 * max_reuse,
            "trtma reuse {r:.3} vs max {max_reuse:.3}"
        );
    }

    #[test]
    fn best_reuse_smallrt_strategy_also_valid() {
        let stages = mk_stages(&[
            &[1, 10],
            &[1, 11],
            &[1, 12],
            &[2, 20],
            &[2, 21],
            &[3, 30],
        ]);
        let mut opts = TrtmaOptions::new(3);
        opts.smallrt_best_reuse = true;
        let buckets = trtma_merge(&stages, opts);
        assert_partition(stages.len(), &buckets);
        assert!(buckets.len() <= 3);
    }

    #[test]
    fn empty() {
        assert!(trtma_merge(&[], TrtmaOptions::new(4)).is_empty());
        assert!(trtma_merge_weighted(&[], TrtmaOptions::new(4), &[1.0]).is_empty());
    }

    #[test]
    fn weighted_with_uniform_costs_equals_trtma() {
        use crate::data::SplitMix64;
        let mut rng = SplitMix64::new(77);
        let mut paths = Vec::new();
        for _ in 0..50 {
            let a = rng.uniform_usize(0, 5) as u64;
            paths.push(vec![a, a * 9 + rng.next_u64() % 3, rng.next_u64() % 17]);
        }
        let stages: Vec<MergeStage> =
            paths.into_iter().enumerate().map(|(i, p)| MergeStage::new(i, p)).collect();
        let a = trtma_merge(&stages, TrtmaOptions::new(6));
        let b = trtma_merge_weighted(&stages, TrtmaOptions::new(6), &[1.0, 1.0, 1.0]);
        assert_eq!(a, b, "uniform weights must reproduce the unit-cost TRTMA");
    }

    #[test]
    fn cost_balance_equalizes_expensive_level(){
        use crate::merging::plan::weighted_tasks;
        // level-1 task is 10x the others; family A stages share it, B's
        // don't exist — craft two families where count-balance leaves a
        // hot bucket that cost-balance splits differently
        let mut paths: Vec<Vec<u64>> = Vec::new();
        for i in 0..8u64 {
            paths.push(vec![1, 100 + i]); // share the expensive task
        }
        for i in 0..4u64 {
            paths.push(vec![2 + i, 200 + i]); // each pays it alone
        }
        let stages: Vec<MergeStage> =
            paths.into_iter().enumerate().map(|(i, p)| MergeStage::new(i, p)).collect();
        let costs = [10.0, 1.0];
        let buckets = trtma_merge_weighted(&stages, TrtmaOptions::new(4), &costs);
        assert_partition(stages.len(), &buckets);
        let mut w: Vec<f64> =
            buckets.iter().map(|b| weighted_tasks(&stages, &b.members, &costs)).collect();
        w.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // the costliest bucket must not exceed the sum/balance bound by much:
        // total weighted work = 10+8 + 4*(10+1) = 62 over 4 buckets => >= 15.5
        let max = *w.last().unwrap();
        assert!(max <= 31.0, "cost-balanced makespan too high: {w:?}");
    }
}
