//! Multi-level computation reuse — the paper's contribution (§3).
//!
//! Two levels:
//!
//! * **Stage-level (coarse-grain)** — [`CompactGraph`] implements
//!   Algorithm 1: identical stage instances across evaluations collapse
//!   into one node of a compact workflow graph.
//! * **Task-level (fine-grain)** — the remaining unique stage instances
//!   are grouped into *buckets* of stages whose common task prefixes
//!   execute once. Four bucketing algorithms, in increasing
//!   sophistication (paper §3.3): [`naive_merge`], [`sca_merge`]
//!   (Smart Cut, min-cut peeling), [`rtma_merge`] (Reuse-Tree), and
//!   [`trtma_merge`] (Task-Balanced Reuse-Tree).
//!
//! [`plan_study`] ties both levels together into the schedulable
//! [`StudyPlan`] the coordinator and the simulator execute.

mod naive;
mod plan;
mod rtma;
mod sca;
mod stage;
mod study;
mod trtma;

pub mod mincut;
pub mod reuse_tree;

pub use naive::naive_merge;
pub use plan::{
    assert_partition, reuse_fraction, stats_for, unique_tasks, weighted_tasks, Bucket,
    MergeStage, PlanStats,
};
pub use rtma::rtma_merge;
pub use sca::sca_merge;
pub use stage::{CompactGraph, CompactNode};
pub use study::{
    batched_unit_cost, plan_study, plan_study_weighted, prune_cached, unit_launch_count,
    unit_stages, FineAlgorithm, ScheduleUnit, StudyPlan, UnitKind, DEFAULT_LAUNCH_COST_SECS,
    DEFAULT_MARGINAL_COST_SECS,
};
pub use trtma::{trtma_merge, trtma_merge_weighted, TrtmaOptions};
