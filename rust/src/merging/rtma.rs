//! Reuse-Tree Merging Algorithm (RTMA) — paper §3.3.3, Algorithm 3.
//!
//! Bottom-up consumption of the reuse tree: at each (deepest) level,
//! every parent of leaves bundles exactly `max_bucket_size` of its leaf
//! children into a bucket (stages bundled at depth ℓ share tasks 1..ℓ);
//! emptied parents are removed recursively; surviving leaves move one
//! level up; repeat. Stages that reach the root unmerged become
//! single-stage buckets (no reuse would be gained, and parallelism is
//! preserved).
//!
//! With the hash-map tree construction the whole algorithm is O(nk)
//! after the O(kn) build — the scalability that lets RTMA replace the
//! O(n⁴) SCA at VBD sample sizes.

use super::plan::{Bucket, MergeStage};
use super::reuse_tree::ReuseTree;

/// Run the RTMA bucketing.
pub fn rtma_merge(stages: &[MergeStage], max_bucket_size: usize) -> Vec<Bucket> {
    assert!(max_bucket_size >= 1);
    if stages.is_empty() {
        return Vec::new();
    }
    let mut t = ReuseTree::build(stages);
    let root = t.root;
    let mut buckets: Vec<Bucket> = Vec::new();

    // Each pass consumes the deepest task level (paper: prune + move-up).
    loop {
        // parents of still-attached leaves, excluding the root (bucketed
        // leaves are detached: parent == None)
        let mut leaf_parents: Vec<usize> = Vec::new();
        for node in &t.nodes {
            if node.is_leaf() {
                let Some(p) = node.parent else { continue };
                if p != root && !leaf_parents.contains(&p) {
                    leaf_parents.push(p);
                }
            }
        }
        if leaf_parents.is_empty() {
            break;
        }

        // prune: bundle exactly max_bucket_size leaves per parent
        for &p in &leaf_parents {
            loop {
                let leaf_children: Vec<usize> = t.nodes[p]
                    .children
                    .iter()
                    .copied()
                    .filter(|&c| t.nodes[c].is_leaf())
                    .collect();
                let bundle_len = if leaf_children.len() >= max_bucket_size {
                    max_bucket_size
                } else if leaf_children.len() >= 2 && t.nodes[p].parent == Some(root) {
                    // Last-chance sub-size bundle: these leaves share tasks
                    // 1..level(p) and moving them to the root would dissolve
                    // that reuse into singletons. The paper's strict
                    // exact-size rule does exactly that, which starves RTMA
                    // on designs with thin sharing groups (MOAT: groups of
                    // 2–5 stages) — measured 3% vs the ~27% potential. This
                    // deviation is documented in DESIGN.md; Fig-11 behaviour
                    // (move-up merging across levels) is unchanged.
                    leaf_children.len()
                } else {
                    break;
                };
                let bundle = &leaf_children[..bundle_len];
                buckets.push(Bucket::of(
                    bundle.iter().map(|&c| t.nodes[c].stage.unwrap()).collect(),
                ));
                t.nodes[p].children.retain(|c| !bundle.contains(c));
                for &c in bundle {
                    t.nodes[c].parent = None; // detach consumed leaves
                }
            }
            // childless parents are removed recursively up the tree
            remove_if_childless(&mut t, p, root);
        }

        // move-up: surviving leaves climb to their grandparent
        for &p in &leaf_parents {
            if t.nodes[p].children.is_empty() {
                continue; // already removed
            }
            let gp = match t.nodes[p].parent {
                Some(gp) => gp,
                None => continue,
            };
            let movers = std::mem::take(&mut t.nodes[p].children);
            for &m in &movers {
                t.nodes[m].parent = Some(gp);
            }
            t.nodes[gp].children.retain(|&c| c != p);
            t.nodes[gp].children.extend(movers);
        }
    }

    // stages left hanging off the root: one-stage buckets
    let root_children: Vec<usize> = t.nodes[root].children.clone();
    for c in root_children {
        if let Some(s) = t.nodes[c].stage {
            buckets.push(Bucket::of(vec![s]));
        }
    }
    buckets
}

fn remove_if_childless(t: &mut ReuseTree, node: usize, root: usize) {
    let mut cur = node;
    while cur != root && t.nodes[cur].children.is_empty() {
        let parent = match t.nodes[cur].parent {
            Some(p) => p,
            None => break,
        };
        t.nodes[parent].children.retain(|&c| c != cur);
        t.nodes[cur].parent = None;
        cur = parent;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merging::plan::{assert_partition, mk_stages, reuse_fraction};

    #[test]
    fn fig11_walkthrough() {
        // Fig. 11: 12 stages, 3 tasks, MaxBucketSize = 3.
        //   a,b,c   share tasks 1-2   (deepest reuse)
        //   d,e,f,g share task 1 (branch A); h,i share task 1 with a-c's
        //   branch; j,k,l are singletons.
        let stages = mk_stages(&[
            /* a */ &[1, 10, 100],
            /* b */ &[1, 10, 101],
            /* c */ &[1, 10, 102],
            /* d */ &[2, 20, 103],
            /* e */ &[2, 21, 104],
            /* f */ &[2, 22, 105],
            /* g */ &[2, 23, 106],
            /* h */ &[1, 11, 107],
            /* i */ &[1, 12, 108],
            /* j */ &[3, 30, 109],
            /* k */ &[4, 40, 110],
            /* l */ &[5, 50, 111],
        ]);
        let buckets = rtma_merge(&stages, 3);
        assert_partition(stages.len(), &buckets);
        // the a,b,c bucket must exist (two shared tasks)
        let abc = buckets.iter().find(|b| {
            let mut m = b.members.clone();
            m.sort();
            m == vec![0, 1, 2]
        });
        assert!(abc.is_some(), "a,b,c share the longest prefix: {buckets:?}");
        // three of d,e,f,g share a bucket
        let defg = buckets
            .iter()
            .find(|b| b.len() == 3 && b.members.iter().all(|&m| (3..=6).contains(&m)));
        assert!(defg.is_some(), "3 of d..g bucketed together: {buckets:?}");
    }

    #[test]
    fn exact_bucket_size_during_merge() {
        // 7 stages all sharing task 1: buckets of exactly 3 until the
        // remainder, which becomes one-stage buckets at the root.
        let stages = mk_stages(&[
            &[1, 2],
            &[1, 3],
            &[1, 4],
            &[1, 5],
            &[1, 6],
            &[1, 7],
            &[1, 8],
        ]);
        let buckets = rtma_merge(&stages, 3);
        assert_partition(stages.len(), &buckets);
        let sizes: Vec<usize> = {
            let mut s: Vec<usize> = buckets.iter().map(Bucket::len).collect();
            s.sort();
            s
        };
        assert_eq!(sizes, vec![1, 3, 3]);
    }

    #[test]
    fn deep_reuse_preferred_over_shallow() {
        // x,y share 3 tasks; z shares only 1 with them. MBS=2 must pick
        // {x,y} and leave z alone.
        let stages = mk_stages(&[&[1, 2, 3, 9], &[1, 2, 3, 8], &[1, 7, 7, 7]]);
        let buckets = rtma_merge(&stages, 2);
        assert_partition(stages.len(), &buckets);
        let xy = buckets.iter().find(|b| b.len() == 2).expect("one pair bucket");
        let mut m = xy.members.clone();
        m.sort();
        assert_eq!(m, vec![0, 1]);
    }

    #[test]
    fn mbs_one_yields_singletons() {
        let stages = mk_stages(&[&[1, 2], &[1, 2], &[1, 3]]);
        let buckets = rtma_merge(&stages, 1);
        assert_partition(stages.len(), &buckets);
        assert_eq!(buckets.len(), 3);
        assert!(buckets.iter().all(|b| b.len() == 1));
    }

    #[test]
    fn large_mbs_merges_everything_reusable() {
        let stages = mk_stages(&[
            &[1, 10, 100],
            &[1, 10, 101],
            &[1, 11, 102],
            &[1, 12, 103],
        ]);
        let buckets = rtma_merge(&stages, 4);
        assert_partition(stages.len(), &buckets);
        assert_eq!(buckets.len(), 1, "all four share task 1: {buckets:?}");
        assert!(reuse_fraction(&stages, &buckets) > 0.0);
    }

    #[test]
    fn no_shared_tasks_all_singletons() {
        let stages = mk_stages(&[&[1, 2], &[3, 4], &[5, 6], &[7, 8]]);
        let buckets = rtma_merge(&stages, 2);
        assert_partition(stages.len(), &buckets);
        // grouping disjoint stages would gain nothing; RTMA leaves them
        // as root-level singletons preserving parallelism
        assert_eq!(buckets.len(), 4);
    }

    #[test]
    fn reuse_close_to_sca_quality() {
        // randomized family structure: RTMA must reach at least the reuse
        // SCA attains (paper: "solutions as good as the ones returned by
        // the SCA")
        use crate::data::SplitMix64;
        let mut rng = SplitMix64::new(99);
        let mut paths = Vec::new();
        for _ in 0..60 {
            let fam = rng.uniform_usize(0, 5) as u64;
            let sub = rng.uniform_usize(0, 3) as u64;
            let leafp = rng.next_u64() % 7;
            paths.push(vec![fam, fam * 10 + sub, leafp]);
        }
        let stages: Vec<MergeStage> =
            paths.into_iter().enumerate().map(|(i, p)| MergeStage::new(i, p)).collect();
        let r_rtma = reuse_fraction(&stages, &rtma_merge(&stages, 5));
        let r_sca = reuse_fraction(&stages, &crate::merging::sca_merge(&stages, 5));
        assert!(
            r_rtma >= r_sca * 0.9,
            "rtma {r_rtma:.3} should be close to sca {r_sca:.3}"
        );
    }

    #[test]
    fn empty() {
        assert!(rtma_merge(&[], 3).is_empty());
    }
}
