//! Naïve fine-grain merging (paper §3.3.1) — the baseline.
//!
//! Groups stages into buckets of `max_bucket_size` **in generation
//! order**. Linear time, but its reuse efficiency is "highly dependent on
//! the stages ordering": it only wins when similar stages happen to be
//! generated adjacently (which MOAT trajectories partially provide).

use super::plan::{Bucket, MergeStage};

/// Sequential bucketing of `stages` in input order.
pub fn naive_merge(stages: &[MergeStage], max_bucket_size: usize) -> Vec<Bucket> {
    assert!(max_bucket_size >= 1, "max_bucket_size must be >= 1");
    (0..stages.len())
        .collect::<Vec<_>>()
        .chunks(max_bucket_size)
        .map(|c| Bucket::of(c.to_vec()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merging::plan::{assert_partition, mk_stages, reuse_fraction};

    #[test]
    fn chunks_in_order() {
        let stages = mk_stages(&[&[1], &[2], &[3], &[4], &[5]]);
        let buckets = naive_merge(&stages, 2);
        assert_eq!(buckets.len(), 3);
        assert_eq!(buckets[0].members, vec![0, 1]);
        assert_eq!(buckets[2].members, vec![4]);
        assert_partition(stages.len(), &buckets);
    }

    #[test]
    fn bucket_size_one_is_no_merging() {
        let stages = mk_stages(&[&[1, 2], &[1, 2]]);
        let buckets = naive_merge(&stages, 1);
        assert_eq!(buckets.len(), 2);
        assert_eq!(reuse_fraction(&stages, &buckets), 0.0);
    }

    #[test]
    fn order_dependence() {
        // adjacent similar stages reuse; interleaved ones don't
        let good = mk_stages(&[&[1, 1], &[1, 2], &[3, 1], &[3, 2]]);
        let bad = mk_stages(&[&[1, 1], &[3, 1], &[1, 2], &[3, 2]]);
        let rg = reuse_fraction(&good, &naive_merge(&good, 2));
        let rb = reuse_fraction(&bad, &naive_merge(&bad, 2));
        assert!(rg > rb, "naive must benefit from favorable ordering ({rg} vs {rb})");
        assert_eq!(rb, 0.0);
    }

    #[test]
    fn empty_input() {
        assert!(naive_merge(&[], 3).is_empty());
    }
}
