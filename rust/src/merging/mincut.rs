//! Minimum-cut machinery for the Smart Cut Algorithm (paper §3.3.2).
//!
//! The SCA models stages as a fully-connected undirected graph whose edge
//! weights are pairwise reuse degrees, and repeatedly 2-cuts it. The
//! paper prices each cut at O(E + V log V) = O(n²) on the dense graph —
//! i.e. one *maximum-adjacency (Stoer–Wagner) phase*, whose
//! cut-of-the-phase separates the last-added vertex from the rest (the
//! "least reusable" stage, exactly the behaviour of Fig. 9). A full
//! Stoer–Wagner min-cut (n phases, O(n³)) is also provided for
//! cross-checking on small graphs.

/// Dense symmetric weight matrix.
#[derive(Clone, Debug)]
pub struct DenseGraph {
    n: usize,
    w: Vec<f64>,
}

impl DenseGraph {
    pub fn new(n: usize) -> Self {
        Self { n, w: vec![0.0; n * n] }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn set(&mut self, a: usize, b: usize, weight: f64) {
        self.w[a * self.n + b] = weight;
        self.w[b * self.n + a] = weight;
    }

    pub fn get(&self, a: usize, b: usize) -> f64 {
        self.w[a * self.n + b]
    }

    /// Restrict to a vertex subset (returns mapping new -> old).
    pub fn subgraph(&self, verts: &[usize]) -> (DenseGraph, Vec<usize>) {
        let mut g = DenseGraph::new(verts.len());
        for (i, &a) in verts.iter().enumerate() {
            for (j, &b) in verts.iter().enumerate().skip(i + 1) {
                g.set(i, j, self.get(a, b));
            }
        }
        (g, verts.to_vec())
    }
}

/// One maximum-adjacency phase over the vertices `active` of `g`:
/// returns `(last_vertex, cut_weight)` — the cut-of-the-phase is
/// `({last}, active \ {last})`.
pub fn ma_phase(g: &DenseGraph, active: &[usize]) -> (usize, f64) {
    assert!(active.len() >= 2, "phase needs >= 2 vertices");
    let mut in_a = vec![false; g.len()];
    let mut conn = vec![0.0f64; g.len()];
    let start = active[0];
    in_a[start] = true;
    for &v in active {
        if v != start {
            conn[v] = g.get(start, v);
        }
    }
    let mut last = start;
    for _ in 1..active.len() {
        // most tightly connected vertex not yet in A
        let mut best = usize::MAX;
        let mut best_w = f64::NEG_INFINITY;
        for &v in active {
            if !in_a[v] && conn[v] > best_w {
                best = v;
                best_w = conn[v];
            }
        }
        in_a[best] = true;
        last = best;
        for &v in active {
            if !in_a[v] {
                conn[v] += g.get(best, v);
            }
        }
    }
    (last, conn[last])
}

/// The SCA 2-cut: split `active` along its global minimum cut (full
/// Stoer–Wagner on the subgraph) into `(larger, smaller)` — Algorithm 2
/// keeps cutting the larger side until it is viable, so the smaller side
/// is the "peeled" set returned to the pool. Minimizing the cut weight
/// minimizes the reuse destroyed by the cut (paper §3.3.2).
pub fn two_cut(g: &DenseGraph, active: &[usize]) -> (Vec<usize>, Vec<usize>) {
    assert!(active.len() >= 2);
    if active.len() == 2 {
        return (vec![active[0]], vec![active[1]]);
    }
    let (sub, map) = g.subgraph(active);
    let (_w, side) = stoer_wagner(&sub);
    let in_side = {
        let mut f = vec![false; sub.len()];
        for &v in &side {
            f[v] = true;
        }
        f
    };
    let a: Vec<usize> = (0..sub.len()).filter(|&v| in_side[v]).map(|v| map[v]).collect();
    let b: Vec<usize> = (0..sub.len()).filter(|&v| !in_side[v]).map(|v| map[v]).collect();
    if a.len() >= b.len() {
        (a, b)
    } else {
        (b, a)
    }
}

/// Full Stoer–Wagner global minimum cut (for validation; O(n³)).
/// Returns (cut_weight, one side of the cut).
pub fn stoer_wagner(g: &DenseGraph) -> (f64, Vec<usize>) {
    let n = g.len();
    assert!(n >= 2);
    // merged vertex groups
    let mut groups: Vec<Vec<usize>> = (0..n).map(|v| vec![v]).collect();
    let mut w = g.clone();
    let mut active: Vec<usize> = (0..n).collect();
    let mut best = (f64::INFINITY, Vec::new());
    while active.len() > 1 {
        // maximum adjacency phase tracking the before-last vertex too
        let mut in_a = vec![false; n];
        let mut conn = vec![0.0f64; n];
        let start = active[0];
        in_a[start] = true;
        for &v in &active {
            if v != start {
                conn[v] = w.get(start, v);
            }
        }
        let (mut s, mut t) = (start, start);
        for _ in 1..active.len() {
            let mut bestv = usize::MAX;
            let mut bw = f64::NEG_INFINITY;
            for &v in &active {
                if !in_a[v] && conn[v] > bw {
                    bestv = v;
                    bw = conn[v];
                }
            }
            in_a[bestv] = true;
            s = t;
            t = bestv;
            for &v in &active {
                if !in_a[v] {
                    conn[v] += w.get(bestv, v);
                }
            }
        }
        if conn[t] < best.0 {
            best = (conn[t], groups[t].clone());
        }
        // merge t into s
        let tg = std::mem::take(&mut groups[t]);
        groups[s].extend(tg);
        for &v in &active {
            if v != s && v != t {
                let nw = w.get(s, v) + w.get(t, v);
                w.set(s, v, nw);
            }
        }
        active.retain(|&v| v != t);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two triangles joined by one weak edge.
    fn barbell() -> DenseGraph {
        let mut g = DenseGraph::new(6);
        for &(a, b) in &[(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5)] {
            g.set(a, b, 10.0);
        }
        g.set(2, 3, 1.0);
        g
    }

    #[test]
    fn stoer_wagner_finds_weak_bridge() {
        let (w, side) = stoer_wagner(&barbell());
        assert_eq!(w, 1.0);
        let mut side = side;
        side.sort();
        assert!(side == vec![0, 1, 2] || side == vec![3, 4, 5]);
    }

    #[test]
    fn ma_phase_peels_least_connected() {
        // star: vertex 3 weakly attached
        let mut g = DenseGraph::new(4);
        g.set(0, 1, 5.0);
        g.set(0, 2, 5.0);
        g.set(1, 2, 5.0);
        g.set(0, 3, 0.5);
        let active: Vec<usize> = (0..4).collect();
        let (last, cut_w) = ma_phase(&g, &active);
        assert_eq!(last, 3);
        assert_eq!(cut_w, 0.5);
    }

    #[test]
    fn two_cut_partitions_along_the_bridge() {
        let g = barbell();
        let active: Vec<usize> = (0..6).collect();
        let (rest, peeled) = two_cut(&g, &active);
        assert_eq!(rest.len() + peeled.len(), 6);
        assert_eq!(rest.len(), 3);
        assert_eq!(peeled.len(), 3);
        let mut p = peeled.clone();
        p.sort();
        assert!(p == vec![0, 1, 2] || p == vec![3, 4, 5]);
        assert!(rest.iter().all(|v| !peeled.contains(v)));
    }

    #[test]
    fn two_cut_subset_of_actives() {
        // restrict to one triangle plus the weak neighbour
        let g = barbell();
        let (rest, peeled) = two_cut(&g, &[2, 3, 4, 5]);
        // min cut separates 2 (weakly attached) from the triangle 3,4,5
        assert_eq!(peeled, vec![2]);
        let mut r = rest.clone();
        r.sort();
        assert_eq!(r, vec![3, 4, 5]);
    }

    #[test]
    fn subgraph_maps_weights() {
        let g = barbell();
        let (sg, map) = g.subgraph(&[3, 4, 5]);
        assert_eq!(sg.len(), 3);
        assert_eq!(sg.get(0, 1), 10.0);
        assert_eq!(map, vec![3, 4, 5]);
    }

    #[test]
    fn stoer_wagner_two_vertices() {
        let mut g = DenseGraph::new(2);
        g.set(0, 1, 3.5);
        let (w, side) = stoer_wagner(&g);
        assert_eq!(w, 3.5);
        assert_eq!(side.len(), 1);
    }
}
