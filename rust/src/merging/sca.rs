//! Smart Cut Algorithm (SCA) — paper §3.3.2, Algorithm 2.
//!
//! Build the fully-connected reuse-degree graph over the stage instances,
//! then repeatedly 2-cut the working graph (peeling its least-reusable
//! stage) until a viable subgraph (≤ `max_bucket_size` stages) remains;
//! emit it as a bucket, return the peeled stages to the pool, repeat.
//!
//! Complexity: O(n²) per cut on the dense graph and O(n²) cuts worst
//! case ⇒ O(n⁴) — the scaling wall the paper demonstrates in Figs. 19/20
//! (SCA never finishes the VBD-sized merges). Kept faithful on purpose;
//! the benches reproduce exactly that blow-up.

use super::mincut::{two_cut, DenseGraph};
use super::plan::{reuse_degree, Bucket, MergeStage};

/// Run the SCA bucketing.
pub fn sca_merge(stages: &[MergeStage], max_bucket_size: usize) -> Vec<Bucket> {
    assert!(max_bucket_size >= 1);
    let n = stages.len();
    if n == 0 {
        return Vec::new();
    }
    // fully-connected reuse graph (paper Fig. 9b)
    let mut g = DenseGraph::new(n);
    for i in 0..n {
        for j in (i + 1)..n {
            g.set(i, j, reuse_degree(&stages[i], &stages[j]) as f64);
        }
    }

    let mut pool: Vec<usize> = (0..n).collect();
    let mut buckets = Vec::new();
    while !pool.is_empty() {
        if pool.len() <= max_bucket_size {
            buckets.push(Bucket::of(pool.clone()));
            break;
        }
        // cut the working set until the surviving side is viable
        let mut work = pool.clone();
        let mut peeled_all: Vec<usize> = Vec::new();
        while work.len() > max_bucket_size {
            let (rest, peeled) = two_cut(&g, &work);
            peeled_all.extend(peeled);
            work = rest;
        }
        buckets.push(Bucket::of(work.clone()));
        // the viable subgraph leaves the pool; peeled stages go back
        pool = peeled_all;
    }
    buckets
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merging::plan::{assert_partition, mk_stages, reuse_fraction};

    #[test]
    fn groups_similar_stages_together() {
        // two families with strong internal reuse, interleaved on purpose
        let stages = mk_stages(&[
            &[1, 1, 1],
            &[9, 9, 9],
            &[1, 1, 2],
            &[9, 9, 8],
            &[1, 1, 3],
            &[9, 9, 7],
        ]);
        let buckets = sca_merge(&stages, 3);
        assert_partition(stages.len(), &buckets);
        assert_eq!(buckets.len(), 2);
        for b in &buckets {
            // each bucket must be a single family: members share a
            // 2-task prefix
            let first = &stages[b.members[0]].path;
            for &m in &b.members {
                assert_eq!(stages[m].path[..2], first[..2]);
            }
        }
        // SCA beats order-based naive on this adversarial ordering
        let naive = crate::merging::naive_merge(&stages, 3);
        assert!(reuse_fraction(&stages, &buckets) > reuse_fraction(&stages, &naive));
    }

    #[test]
    fn respects_max_bucket_size() {
        let stages = mk_stages(&[&[1], &[1], &[1], &[1], &[1], &[1], &[1]]);
        for mbs in 1..=4 {
            let buckets = sca_merge(&stages, mbs);
            assert_partition(stages.len(), &buckets);
            assert!(buckets.iter().all(|b| b.len() <= mbs));
        }
    }

    #[test]
    fn single_stage() {
        let stages = mk_stages(&[&[1, 2, 3]]);
        let buckets = sca_merge(&stages, 4);
        assert_eq!(buckets.len(), 1);
        assert_eq!(buckets[0].members, vec![0]);
    }

    #[test]
    fn empty() {
        assert!(sca_merge(&[], 3).is_empty());
    }

    #[test]
    fn fig9_walkthrough() {
        // Fig. 9: 5 instances of a 6-task workflow, MaxBucketSize = 2.
        // d and e are the most-reusing pair; a, b, c are progressively
        // less related. The first bucket must be {d, e}.
        let stages = mk_stages(&[
            /* a */ &[1, 10, 20, 33, 43, 50],
            /* b */ &[1, 10, 21, 31, 41, 51],
            /* c */ &[2, 11, 22, 32, 42, 52],
            /* d */ &[1, 10, 20, 30, 40, 53],
            /* e */ &[1, 10, 20, 30, 40, 54],
        ]);
        let buckets = sca_merge(&stages, 2);
        assert_partition(stages.len(), &buckets);
        let de = buckets.iter().find(|b| {
            let mut m = b.members.clone();
            m.sort();
            m == vec![3, 4]
        });
        assert!(de.is_some(), "d+e must share a bucket: {buckets:?}");
        // c is the least reusable and ends up alone or with b, never
        // splitting the d/e pair
    }
}
