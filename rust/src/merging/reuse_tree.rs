//! The Reuse-Tree structure (paper §3.3.3).
//!
//! Level ℓ of the tree represents task ℓ of the stage; a node stands for
//! one distinct task instantiation, and two stages share a node at level
//! ℓ iff their tasks 1..ℓ are pairwise identical (same computation, same
//! inputs) — i.e. reusable among themselves. Every stage terminates in
//! its own *leaf node* below its last task node, exactly as the paper
//! draws it (Fig. 11: stage letters hang below the task levels).
//!
//! Construction uses a hash-map child lookup, giving the O(kn) bound of
//! the paper's optimized GenerateReuseTree.

use std::collections::HashMap;

use super::plan::MergeStage;

/// One reuse-tree node: either a task node (`stage == None`) or a stage
/// leaf (`stage == Some(idx)`, always childless).
#[derive(Clone, Debug)]
pub struct RtNode {
    /// Task signature at this level (0 for the root and for leaves).
    pub sig: u64,
    /// 0 = root; tasks at 1..=k; stage leaves at k+1.
    pub level: usize,
    pub parent: Option<usize>,
    pub children: Vec<usize>,
    /// For stage leaves: the stage (index into the merge input).
    pub stage: Option<usize>,
}

impl RtNode {
    pub fn is_leaf(&self) -> bool {
        self.stage.is_some()
    }
}

/// Arena-allocated reuse tree.
#[derive(Clone, Debug)]
pub struct ReuseTree {
    pub nodes: Vec<RtNode>,
    pub root: usize,
    /// Task levels (path length of the inserted stages).
    pub n_levels: usize,
}

impl ReuseTree {
    /// Insert every stage one task-node at a time, reusing existing nodes
    /// with equal (parent, signature), then attach the stage leaf.
    pub fn build(stages: &[MergeStage]) -> Self {
        let mut nodes = vec![RtNode {
            sig: 0,
            level: 0,
            parent: None,
            children: Vec::new(),
            stage: None,
        }];
        let mut lookup: HashMap<(usize, u64), usize> = HashMap::new();
        let n_levels = stages.first().map(|s| s.path.len()).unwrap_or(0);
        for (idx, st) in stages.iter().enumerate() {
            assert_eq!(st.path.len(), n_levels, "stage paths must have equal length");
            let mut cur = 0usize;
            for (li, &sig) in st.path.iter().enumerate() {
                let key = (cur, sig);
                cur = match lookup.get(&key) {
                    Some(&c) => c,
                    None => {
                        let id = nodes.len();
                        nodes.push(RtNode {
                            sig,
                            level: li + 1,
                            parent: Some(cur),
                            children: Vec::new(),
                            stage: None,
                        });
                        nodes[cur].children.push(id);
                        lookup.insert(key, id);
                        id
                    }
                };
            }
            let leaf = nodes.len();
            nodes.push(RtNode {
                sig: 0,
                level: n_levels + 1,
                parent: Some(cur),
                children: Vec::new(),
                stage: Some(idx),
            });
            nodes[cur].children.push(leaf);
        }
        ReuseTree { nodes, root: 0, n_levels }
    }

    /// Number of task executions the whole tree represents: one per task
    /// node (root and stage leaves carry no work).
    pub fn unique_task_count(&self) -> usize {
        self.nodes.iter().filter(|n| !n.is_leaf()).count() - 1
    }

    /// Stage indices of all leaves under `node` (inclusive).
    pub fn leaves_under(&self, node: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut stack = vec![node];
        while let Some(v) = stack.pop() {
            if let Some(s) = self.nodes[v].stage {
                out.push(s);
            }
            stack.extend(self.nodes[v].children.iter().copied());
        }
        out
    }

    /// All leaf node ids (one per inserted stage).
    pub fn leaf_nodes(&self) -> Vec<usize> {
        (0..self.nodes.len()).filter(|&i| self.nodes[i].is_leaf()).collect()
    }

    /// Height = node levels on the longest root-to-leaf path including
    /// the root and the stage-leaf level (bare root has height 1).
    pub fn height(&self) -> usize {
        fn depth(t: &ReuseTree, v: usize) -> usize {
            1 + t.nodes[v].children.iter().map(|&c| depth(t, c)).max().unwrap_or(0)
        }
        depth(self, self.root)
    }

    /// Any member (stage index into the merge input) whose leaf lies
    /// under `node` — all members below a task node share the task
    /// prefix down to it, so any one resolves the node's task.
    pub fn first_member(&self, node: usize) -> usize {
        let mut v = node;
        loop {
            if let Some(s) = self.nodes[v].stage {
                return s;
            }
            v = self.nodes[v].children[0];
        }
    }

    /// The frontier-order (level-synchronous BFS) walk of the tree: one
    /// `Vec<WalkNode>` per level, task levels `1..=n_levels` first, the
    /// stage-leaf level last. This is THE canonical traversal — the
    /// executor (`coordinator/exec.rs`) batches each level's task nodes
    /// into kernel launches, and the planning probe
    /// (`merging/study.rs::prune_cached`) counts cached nodes over the
    /// same walk, so the two can never drift.
    pub fn walk(&self) -> Vec<Vec<WalkNode>> {
        let mut levels: Vec<Vec<WalkNode>> = vec![Vec::new(); self.n_levels + 1];
        for (id, n) in self.nodes.iter().enumerate() {
            if id == self.root {
                continue;
            }
            levels[n.level - 1].push(WalkNode {
                node: id,
                parent: n.parent.expect("non-root node has a parent"),
                level: n.level,
                member: self.first_member(id),
                stage: n.stage,
            });
        }
        levels
    }

    /// Content chain keys for every tree node, derived over a
    /// precomputed [`walk`] (callers already hold the walk for
    /// execution/probing — pass it in rather than traversing twice):
    /// the root carries `base`, and each task node extends its parent's
    /// key with `task_sig(level, member)` — the caller resolves the task
    /// signature exactly as it resolves the task to execute. Leaves
    /// inherit nothing (they carry no work); their slots keep `base`.
    ///
    /// [`walk`]: ReuseTree::walk
    pub fn chain_keys(
        &self,
        levels: &[Vec<WalkNode>],
        base: crate::cache::Key,
        mut task_sig: impl FnMut(usize, usize) -> u64,
    ) -> Vec<crate::cache::Key> {
        let mut keys = vec![base; self.nodes.len()];
        for level in levels {
            for n in level {
                if n.stage.is_none() {
                    keys[n.node] =
                        crate::cache::chain_key(keys[n.parent], task_sig(n.level, n.member));
                }
            }
        }
        keys
    }
}

/// One node of a frontier level (see [`ReuseTree::walk`]): a task node
/// (`stage == None`) to execute, or a stage leaf (`stage == Some(s)`)
/// whose parent state materializes member `s`'s output.
#[derive(Clone, Copy, Debug)]
pub struct WalkNode {
    /// Tree node id.
    pub node: usize,
    /// Parent tree node id (the state this node consumes).
    pub parent: usize,
    /// 1-based task level (`n_levels + 1` for stage leaves).
    pub level: usize,
    /// A member (stage index) whose leaf lies under this node — resolves
    /// the node's task at `level`.
    pub member: usize,
    /// For stage leaves: the member this leaf terminates.
    pub stage: Option<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merging::plan::mk_stages;

    #[test]
    fn fig10_insertion() {
        // Fig. 10: stages a..d over tasks (p1, p2, p3); then x = (8, 2, 9)
        // is inserted, reusing the p1=8 node and creating new nodes for
        // its 2nd and 3rd tasks (plus x's leaf).
        let before = mk_stages(&[
            /* a */ &[7, 1, 4],
            /* b */ &[7, 3, 4],
            /* c */ &[7, 3, 5],
            /* d */ &[8, 5, 6],
        ]);
        let t0 = ReuseTree::build(&before);
        // root + level1 {7,8} + level2 {1,3,5} + level3 {4,4',5,6} + 4 leaves
        assert_eq!(t0.nodes.len(), 1 + 2 + 3 + 4 + 4);
        assert_eq!(t0.unique_task_count(), 9);

        let after = mk_stages(&[
            &[7, 1, 4],
            &[7, 3, 4],
            &[7, 3, 5],
            &[8, 5, 6],
            /* x */ &[8, 2, 9],
        ]);
        let t1 = ReuseTree::build(&after);
        // x reuses node "8" and adds exactly 2 task nodes + 1 leaf
        assert_eq!(t1.nodes.len(), t0.nodes.len() + 3);
        assert_eq!(t1.unique_task_count(), 11);
        assert_eq!(t1.leaves_under(t1.root).len(), 5);
    }

    #[test]
    fn duplicate_full_paths_share_all_tasks() {
        let stages = mk_stages(&[&[1, 2], &[1, 2], &[1, 2]]);
        let t = ReuseTree::build(&stages);
        let mut leaves: Vec<usize> =
            t.leaf_nodes().iter().map(|&n| t.nodes[n].stage.unwrap()).collect();
        leaves.sort();
        assert_eq!(leaves, vec![0, 1, 2]);
        // three identical stages cost 2 unique tasks, not 6
        assert_eq!(t.unique_task_count(), 2);
    }

    #[test]
    fn unique_task_count_matches_plan_helper() {
        let stages = mk_stages(&[&[1, 5, 9, 13], &[1, 5, 2, 7], &[1, 5, 9, 15]]);
        let t = ReuseTree::build(&stages);
        let all: Vec<usize> = (0..stages.len()).collect();
        assert_eq!(t.unique_task_count(), super::super::plan::unique_tasks(&stages, &all));
        assert_eq!(t.unique_task_count(), 7);
    }

    #[test]
    fn height_and_leaves() {
        let stages = mk_stages(&[&[1, 2, 3], &[1, 2, 4], &[9, 9, 9]]);
        let t = ReuseTree::build(&stages);
        assert_eq!(t.height(), 5); // root + 3 task levels + leaf level
        assert_eq!(t.n_levels, 3);
        let mut ls = t.leaves_under(t.root);
        ls.sort();
        assert_eq!(ls, vec![0, 1, 2]);
    }

    #[test]
    fn no_reuse_tree_is_a_star_of_chains() {
        let stages = mk_stages(&[&[1, 2], &[3, 4], &[5, 6]]);
        let t = ReuseTree::build(&stages);
        assert_eq!(t.nodes[t.root].children.len(), 3);
        assert_eq!(t.unique_task_count(), 6);
    }

    #[test]
    fn leaves_are_childless_and_tasks_carry_no_stage() {
        let stages = mk_stages(&[&[1, 2, 3], &[1, 9, 9]]);
        let t = ReuseTree::build(&stages);
        for n in &t.nodes {
            if n.is_leaf() {
                assert!(n.children.is_empty());
                assert_eq!(n.level, t.n_levels + 1);
            }
        }
    }

    #[test]
    fn empty_input() {
        let t = ReuseTree::build(&[]);
        assert_eq!(t.nodes.len(), 1);
        assert_eq!(t.unique_task_count(), 0);
        assert!(t.leaves_under(t.root).is_empty());
        assert!(t.walk().iter().all(|l| l.is_empty()));
    }

    #[test]
    fn walk_visits_every_node_once_in_level_order() {
        let stages = mk_stages(&[&[1, 2, 3], &[1, 2, 4], &[1, 9, 9], &[7, 8, 9]]);
        let t = ReuseTree::build(&stages);
        let levels = t.walk();
        assert_eq!(levels.len(), t.n_levels + 1);
        let mut seen = vec![false; t.nodes.len()];
        seen[t.root] = true;
        for (li, level) in levels.iter().enumerate() {
            for n in level {
                assert_eq!(n.level, li + 1);
                assert_eq!(t.nodes[n.node].level, n.level);
                assert_eq!(t.nodes[n.node].parent, Some(n.parent));
                assert!(seen[n.parent], "parents precede children");
                assert!(!seen[n.node], "node visited twice");
                seen[n.node] = true;
                assert_eq!(n.stage, t.nodes[n.node].stage);
                // the member's leaf lies under the node
                assert!(t.leaves_under(n.node).contains(&n.member));
            }
        }
        assert!(seen.iter().all(|&s| s), "walk must cover the whole tree");
        // the last level is exactly the stage leaves
        assert!(levels[t.n_levels].iter().all(|n| n.stage.is_some()));
        assert_eq!(levels[t.n_levels].len(), stages.len());
    }

    #[test]
    fn chain_keys_fold_parent_keys_through_task_sigs() {
        use crate::cache::Key;
        let stages = mk_stages(&[&[1, 2], &[1, 3]]);
        let t = ReuseTree::build(&stages);
        // sig = level * 100 + member-resolved path entry
        let levels = t.walk();
        let base = Key::from(7u64);
        let keys =
            t.chain_keys(&levels, base, |level, member| stages[member].path[level - 1] * 100);
        // manual recursion over the same definition
        fn expect(t: &ReuseTree, node: usize, key: Key, stages: &[MergeStage], keys: &[Key]) {
            assert_eq!(keys[node], key);
            for &c in &t.nodes[node].children {
                if t.nodes[c].stage.is_some() {
                    continue;
                }
                let member = t.first_member(c);
                let sig = stages[member].path[t.nodes[c].level - 1] * 100;
                expect(t, c, crate::cache::chain_key(key, sig), stages, keys);
            }
        }
        expect(&t, t.root, base, &stages, &keys);
        // shared prefix node -> shared key; divergent second level -> distinct
        let l1 = &t.walk()[0];
        assert_eq!(l1.len(), 1, "both stages share the level-1 node");
        let l2 = &t.walk()[1];
        assert_eq!(l2.len(), 2);
        assert_ne!(keys[l2[0].node], keys[l2[1].node]);
    }
}
