//! Shared types for the fine-grain merging algorithms.

use std::collections::HashSet;

/// One stage instance as the merging algorithms see it: an opaque id and
/// its reuse path (one task signature per level). All stages offered to a
/// single merge call share the same stage type and input signature, so
/// *prefix equality of paths* ⇔ *task reusability* (paper §3.3.3).
#[derive(Clone, Debug, PartialEq)]
pub struct MergeStage {
    /// Caller-side identity (e.g. compact-graph node index).
    pub id: usize,
    /// Task signatures level by level.
    pub path: Vec<u64>,
    /// Chained prefix signatures (see [`prefix_sigs`]), precomputed so
    /// TaskCost evaluations never re-hash the path.
    pub prefixes: Vec<u64>,
}

impl MergeStage {
    pub fn new(id: usize, path: Vec<u64>) -> Self {
        let prefixes = prefix_sigs(&path);
        Self { id, path, prefixes }
    }
}

/// A bucket of stages merged for joint execution: the stages' common task
/// prefixes execute once.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Bucket {
    /// Indices into the merge call's stage slice.
    pub members: Vec<usize>,
}

impl Bucket {
    pub fn of(members: Vec<usize>) -> Self {
        Self { members }
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }
}

/// Identity hasher for values that are already hashes (the chained
/// prefix signatures below). Removes the SipHash cost from the
/// TaskCost evaluations that dominate TRTMA's balance search
/// (EXPERIMENTS.md §Perf).
#[derive(Clone, Copy, Default)]
pub struct SigHasher(u64);

impl std::hash::Hasher for SigHasher {
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = self.0.rotate_left(8) ^ b as u64;
        }
    }
    fn write_u64(&mut self, v: u64) {
        self.0 ^= v;
    }
}

/// `BuildHasher` for [`SigHasher`].
pub type SigBuild = std::hash::BuildHasherDefault<SigHasher>;

/// Per-stage chained prefix signatures: element `l` identifies the task
/// prefix `path[..=l]` (level folded in, so cross-level collisions are
/// excluded by construction).
pub fn prefix_sigs(path: &[u64]) -> Vec<u64> {
    let mut out = Vec::with_capacity(path.len());
    let mut acc: u64 = 0xcbf29ce484222325;
    for (level, &sig) in path.iter().enumerate() {
        acc = acc.wrapping_mul(0x100000001b3) ^ sig;
        // fold the level in so equal signatures at different depths differ
        out.push(acc ^ ((level as u64).wrapping_mul(0x9e3779b97f4a7c15)));
    }
    out
}

/// Number of *unique* tasks a set of stages executes when merged: the
/// count of distinct path prefixes (the trie size, paper's TaskCost).
pub fn unique_tasks(stages: &[MergeStage], members: &[usize]) -> usize {
    let mut seen: HashSet<u64, SigBuild> = HashSet::default();
    let mut count = 0usize;
    for &m in members {
        for &sig in &stages[m].prefixes {
            if seen.insert(sig) {
                count += 1;
            }
        }
    }
    count
}

/// Cost-weighted variant of [`unique_tasks`]: each distinct prefix at
/// level `l` contributes `level_costs[l]` (estimated seconds of the
/// stage's `l`-th task) instead of 1. This is the bucket-cost function
/// of the cost-balanced TRTMA (paper §5 future work).
pub fn weighted_tasks(stages: &[MergeStage], members: &[usize], level_costs: &[f64]) -> f64 {
    let mut seen: HashSet<u64, SigBuild> = HashSet::default();
    let mut total = 0.0;
    for &m in members {
        for (level, &sig) in stages[m].prefixes.iter().enumerate() {
            if seen.insert(sig) {
                total += level_costs.get(level).copied().unwrap_or(1.0);
            }
        }
    }
    total
}

/// Length of the common path prefix of two stages — the paper's "degree
/// of reuse" edge weight in the SCA graph.
pub fn reuse_degree(a: &MergeStage, b: &MergeStage) -> usize {
    a.path.iter().zip(&b.path).take_while(|(x, y)| x == y).count()
}

/// Aggregate statistics of a bucketing.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PlanStats {
    pub stages: usize,
    pub buckets: usize,
    /// Tasks executed without fine-grain reuse (n·k).
    pub tasks_replica: usize,
    /// Tasks executed with the bucketing (Σ bucket unique tasks).
    pub tasks_merged: usize,
}

impl PlanStats {
    /// Fraction of task executions removed by the merging (paper ~33 %).
    pub fn reuse(&self) -> f64 {
        if self.tasks_replica == 0 {
            0.0
        } else {
            1.0 - self.tasks_merged as f64 / self.tasks_replica as f64
        }
    }
}

/// Compute [`PlanStats`] for a bucketing of `stages`.
pub fn stats_for(stages: &[MergeStage], buckets: &[Bucket]) -> PlanStats {
    let tasks_replica: usize = stages.iter().map(|s| s.path.len()).sum();
    let tasks_merged: usize = buckets.iter().map(|b| unique_tasks(stages, &b.members)).sum();
    PlanStats { stages: stages.len(), buckets: buckets.len(), tasks_replica, tasks_merged }
}

/// Fraction of tasks saved by `buckets` relative to replica execution.
pub fn reuse_fraction(stages: &[MergeStage], buckets: &[Bucket]) -> f64 {
    stats_for(stages, buckets).reuse()
}

/// Debug-check that a bucketing is a partition of `0..n`.
pub fn assert_partition(n: usize, buckets: &[Bucket]) {
    let mut seen = vec![false; n];
    for b in buckets {
        for &m in &b.members {
            assert!(m < n, "member {m} out of range {n}");
            assert!(!seen[m], "stage {m} in two buckets");
            seen[m] = true;
        }
    }
    assert!(seen.iter().all(|&s| s), "not all stages bucketed");
}

#[cfg(test)]
pub(crate) fn mk_stages(paths: &[&[u64]]) -> Vec<MergeStage> {
    paths.iter().enumerate().map(|(i, p)| MergeStage::new(i, p.to_vec())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unique_tasks_counts_trie_nodes() {
        // paper Fig. 6: sets {A1,B5,C9,D13}, {A1,B5,C2,D7}, {A1,B5,C9,D15}
        // -> 7 unique tasks instead of 12
        let stages = mk_stages(&[&[1, 5, 9, 13], &[1, 5, 2, 7], &[1, 5, 9, 15]]);
        assert_eq!(unique_tasks(&stages, &[0, 1, 2]), 7);
        assert_eq!(unique_tasks(&stages, &[0]), 4);
        assert_eq!(unique_tasks(&stages, &[0, 1]), 6);
        assert_eq!(unique_tasks(&stages, &[]), 0);
    }

    #[test]
    fn unique_tasks_no_false_sharing_across_levels() {
        // same signature at different levels must not collide
        let stages = mk_stages(&[&[7, 7], &[7, 8]]);
        assert_eq!(unique_tasks(&stages, &[0, 1]), 3);
    }

    #[test]
    fn prefix_only_reuse() {
        // identical suffix but different first task -> nothing shared
        let stages = mk_stages(&[&[1, 5, 9], &[2, 5, 9]]);
        assert_eq!(unique_tasks(&stages, &[0, 1]), 6);
    }

    #[test]
    fn reuse_degree_is_common_prefix() {
        let stages = mk_stages(&[&[1, 5, 9, 13], &[1, 5, 2, 7], &[2, 5, 9, 13]]);
        assert_eq!(reuse_degree(&stages[0], &stages[1]), 2);
        assert_eq!(reuse_degree(&stages[0], &stages[2]), 0);
        assert_eq!(reuse_degree(&stages[0], &stages[0]), 4);
    }

    #[test]
    fn stats_and_reuse() {
        let stages = mk_stages(&[&[1, 5, 9, 13], &[1, 5, 2, 7], &[1, 5, 9, 15]]);
        let buckets = vec![Bucket::of(vec![0, 1, 2])];
        let st = stats_for(&stages, &buckets);
        assert_eq!(st.tasks_replica, 12);
        assert_eq!(st.tasks_merged, 7);
        assert!((st.reuse() - 5.0 / 12.0).abs() < 1e-12);
    }
}
