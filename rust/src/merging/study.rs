//! Study planning: both reuse levels composed into a schedulable plan.
//!
//! [`plan_study`] takes the coarse-grain [`CompactGraph`] (Algorithm 1
//! output) and applies one of the fine-grain merging algorithms to every
//! *merge group* — the compact nodes of one stage level sharing the same
//! input signature, i.e. exactly the stage instances the paper's
//! task-level merging may bundle. The result is a [`StudyPlan`] of
//! [`ScheduleUnit`]s with explicit inter-unit dependencies; the
//! coordinator (real PJRT execution) and the simulator (scaling studies)
//! both consume this plan.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::cache::{metrics_key, node_input_key, task_cache_sig, Key, ReuseCache};
use crate::workflow::StageInstance;

use super::plan::{unique_tasks, Bucket, MergeStage, PlanStats};
use super::reuse_tree::ReuseTree;
use super::stage::CompactGraph;
use super::{naive_merge, rtma_merge, sca_merge, trtma_merge, trtma_merge_weighted, TrtmaOptions};

/// Which fine-grain (task-level) merging algorithm to run on top of the
/// coarse-grain compact graph.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FineAlgorithm {
    /// Coarse-grain reuse only (the paper's "Stage Level" version).
    None,
    /// Naïve sequential bucketing (paper §3.3.1), `MaxBucketSize` stages.
    Naive(usize),
    /// Smart Cut min-cut peeling (paper §3.3.2), `MaxBucketSize` stages.
    Sca(usize),
    /// Reuse-Tree merging (paper §3.3.3), `MaxBucketSize` stages.
    Rtma(usize),
    /// Task-Balanced Reuse-Tree merging (paper §3.3.4). The target bucket
    /// count applies *per merge group* (one group per stage level × input
    /// signature; the paper's single-tile studies have one big group, so
    /// this matches its global `MaxBuckets`).
    Trtma(TrtmaOptions),
    /// Cost-balanced TRTMA (the paper's §5 future work): buckets
    /// balanced by estimated task *cost* (per-task seconds supplied to
    /// [`plan_study_weighted`]) instead of task count, removing the
    /// Fig.-24 topology imbalance.
    TrtmaCost(TrtmaOptions),
}

impl FineAlgorithm {
    /// Short display name matching the paper's figure legends.
    pub fn name(&self) -> &'static str {
        match self {
            FineAlgorithm::None => "stage-level",
            FineAlgorithm::Naive(_) => "naive",
            FineAlgorithm::Sca(_) => "sca",
            FineAlgorithm::Rtma(_) => "rtma",
            FineAlgorithm::Trtma(_) => "trtma",
            FineAlgorithm::TrtmaCost(_) => "trtma-cost",
        }
    }

    fn run(&self, stages: &[MergeStage], level_costs: &[f64]) -> Vec<Bucket> {
        match *self {
            FineAlgorithm::None => {
                (0..stages.len()).map(|i| Bucket::of(vec![i])).collect()
            }
            FineAlgorithm::Naive(mbs) => naive_merge(stages, mbs),
            FineAlgorithm::Sca(mbs) => sca_merge(stages, mbs),
            FineAlgorithm::Rtma(mbs) => rtma_merge(stages, mbs),
            FineAlgorithm::Trtma(opts) => trtma_merge(stages, opts),
            FineAlgorithm::TrtmaCost(opts) => trtma_merge_weighted(stages, opts, level_costs),
        }
    }
}

/// How a schedule unit came to be.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum UnitKind {
    /// One compact node, not fine-grain merged (singleton or `None`).
    Single,
    /// A bucket of ≥ 2 compact nodes sharing task prefixes.
    Merged,
}

/// One schedulable work item: a bucket of compact-graph nodes of the same
/// stage level and input, executed jointly on one worker with the common
/// task prefixes running once.
#[derive(Clone, Debug)]
pub struct ScheduleUnit {
    pub id: usize,
    pub stage: String,
    pub stage_idx: usize,
    /// Compact-graph node ids bundled into this unit.
    pub nodes: Vec<usize>,
    /// Unit ids that must complete before this unit can run.
    pub deps: Vec<usize>,
    pub kind: UnitKind,
    /// Unique fine-grain tasks this unit executes (the paper's TaskCost).
    pub task_cost: usize,
}

/// The complete two-level reuse plan for a study.
#[derive(Clone, Debug)]
pub struct StudyPlan {
    pub units: Vec<ScheduleUnit>,
    /// compact node id → owning unit id.
    pub node_unit: Vec<usize>,
    /// Stage instances removed by coarse-grain merging.
    pub coarse_saved: usize,
    /// Fine-grain task statistics over the *post-coarse* instances
    /// (Table 4 reports exactly this "fine reuse after coarse reuse").
    pub fine: PlanStats,
    /// Wall time spent inside the fine-grain merging algorithm — the
    /// overhead plotted on top of the bars in Figs 19/20.
    pub merge_time: Duration,
    /// Tasks [`prune_cached`] predicts the cross-study cache will serve
    /// (0 until a cache-aware planning pass runs).
    pub cached_tasks: usize,
}

impl StudyPlan {
    /// Fine-grain reuse fraction (paper ≈ 33–36 %).
    pub fn fine_reuse(&self) -> f64 {
        self.fine.reuse()
    }

    /// Total fine-grain tasks the plan executes.
    pub fn tasks_to_execute(&self) -> usize {
        self.units.iter().map(|u| u.task_cost).sum()
    }

    /// Units per stage level, for parallelism diagnostics.
    pub fn units_of_stage(&self, stage_idx: usize) -> Vec<usize> {
        self.units
            .iter()
            .filter(|u| u.stage_idx == stage_idx)
            .map(|u| u.id)
            .collect()
    }

    /// Check plan integrity: every node in exactly one unit, deps point
    /// to earlier stage levels. Panics on violation (test helper).
    pub fn assert_valid(&self, graph: &CompactGraph) {
        let mut seen = vec![false; graph.nodes.len()];
        for u in &self.units {
            for &n in &u.nodes {
                assert!(!seen[n], "node {n} in two units");
                seen[n] = true;
                assert_eq!(self.node_unit[n], u.id);
            }
            for &d in &u.deps {
                assert!(
                    self.units[d].stage_idx < u.stage_idx,
                    "dep {} not upstream of unit {}",
                    d,
                    u.id
                );
            }
        }
        assert!(seen.iter().all(|&s| s), "unassigned compact node");
    }
}

/// Build the fine-grain merge groups: compact nodes keyed by
/// (stage level, input signature). Only instances with identical inputs
/// may share task results.
fn merge_groups(
    graph: &CompactGraph,
    instances: &[StageInstance],
) -> Vec<Vec<usize>> {
    let mut groups: HashMap<(usize, u64), Vec<usize>> = HashMap::new();
    for node in &graph.nodes {
        let rep = &instances[node.rep];
        groups.entry((node.stage_idx, rep.input_sig)).or_default().push(node.id);
    }
    let mut out: Vec<Vec<usize>> = groups.into_values().collect();
    // deterministic planning order: by first node id
    out.sort_by_key(|g| g.iter().copied().min().unwrap_or(0));
    out
}

/// Compose coarse- and fine-grain reuse into a [`StudyPlan`] with unit
/// task costs (every task weighs 1 — the paper's algorithms).
pub fn plan_study(
    graph: &CompactGraph,
    instances: &[StageInstance],
    algo: FineAlgorithm,
) -> StudyPlan {
    plan_study_weighted(graph, instances, algo, &HashMap::new())
}

/// Like [`plan_study`], with per-task cost estimates (task name →
/// seconds) used by [`FineAlgorithm::TrtmaCost`]; unknown tasks weigh 1.
pub fn plan_study_weighted(
    graph: &CompactGraph,
    instances: &[StageInstance],
    algo: FineAlgorithm,
    task_costs: &HashMap<String, f64>,
) -> StudyPlan {
    let mut units: Vec<ScheduleUnit> = Vec::new();
    let mut node_unit = vec![usize::MAX; graph.nodes.len()];
    let mut tasks_replica = 0usize;
    let mut tasks_merged = 0usize;
    let mut merge_time = Duration::ZERO;

    for group in merge_groups(graph, instances) {
        // Paths of the group's members, in group order.
        let stages: Vec<MergeStage> = group
            .iter()
            .enumerate()
            .map(|(i, &n)| MergeStage::new(i, instances[graph.nodes[n].rep].task_path()))
            .collect();
        tasks_replica += stages.iter().map(|s| s.path.len()).sum::<usize>();

        let buckets = if group.len() >= 2 && !stages[0].path.is_empty() {
            // per-level cost estimates for this group's stage type
            let rep = &instances[graph.nodes[group[0]].rep];
            let level_costs: Vec<f64> = rep
                .tasks
                .iter()
                .map(|t| task_costs.get(&t.name).copied().unwrap_or(1.0))
                .collect();
            let t0 = Instant::now();
            let b = algo.run(&stages, &level_costs);
            merge_time += t0.elapsed();
            b
        } else {
            (0..group.len()).map(|i| Bucket::of(vec![i])).collect()
        };

        for b in &buckets {
            let cost = unique_tasks(&stages, &b.members);
            tasks_merged += cost;
            let nodes: Vec<usize> = b.members.iter().map(|&m| group[m]).collect();
            let id = units.len();
            for &n in &nodes {
                node_unit[n] = id;
            }
            units.push(ScheduleUnit {
                id,
                stage: graph.nodes[nodes[0]].stage.clone(),
                stage_idx: graph.nodes[nodes[0]].stage_idx,
                nodes,
                deps: Vec::new(),
                kind: if b.members.len() > 1 { UnitKind::Merged } else { UnitKind::Single },
                task_cost: cost,
            });
        }
    }

    // dependencies: a unit depends on the units owning its nodes' parents
    for u in units.iter_mut() {
        let mut deps: Vec<usize> = u
            .nodes
            .iter()
            .filter_map(|&n| graph.nodes[n].parent)
            .map(|p| node_unit[p])
            .collect();
        deps.sort_unstable();
        deps.dedup();
        u.deps = deps;
    }

    StudyPlan {
        coarse_saved: graph.stages_saved(),
        fine: PlanStats {
            stages: graph.nodes.len(),
            buckets: units.len(),
            tasks_replica,
            tasks_merged,
        },
        units,
        node_unit,
        merge_time,
        cached_tasks: 0,
    }
}

/// Cache-aware planning pass: probe the cross-study cache for every task
/// the plan would execute and subtract the hits from each unit's
/// `task_cost`, so (a) the LPT dispatch order reflects the work that will
/// *actually* run and (b) callers can report predicted cross-study reuse
/// before spending any engine time. `tile_fps` keys tile ids to content
/// fingerprints ([`crate::cache::tile_fingerprints`]); comparison units
/// additionally need `ref_fps` (reference-mask fingerprints) and
/// `compare_task` to recognize the metric-cached stage.
///
/// Returns the number of tasks predicted cached (also recorded in
/// [`StudyPlan::cached_tasks`]). The probe mirrors execution exactly:
/// every reuse-tree task node whose chain key is present in the cache is
/// one skipped execution.
pub fn prune_cached(
    plan: &mut StudyPlan,
    graph: &CompactGraph,
    instances: &[StageInstance],
    cache: &ReuseCache,
    tile_fps: &HashMap<u64, Key>,
    ref_fps: &HashMap<u64, Key>,
    compare_task: &str,
) -> usize {
    let step = cache.quantize_step();
    let mut pruned_total = 0usize;
    for u in plan.units.iter_mut() {
        let rep = &instances[graph.nodes[u.nodes[0]].rep];
        let tile_fp = tile_fps.get(&rep.tile).copied().unwrap_or(Key::from(0u64));
        let base = node_input_key(graph, instances, u.nodes[0], tile_fp, step);
        let pruned = if rep.tasks.len() == 1 && rep.tasks[0].name == compare_task {
            let ref_fp = ref_fps.get(&rep.tile).copied().unwrap_or(Key::from(0u64));
            let key = metrics_key(base, task_cache_sig(&rep.tasks[0], step), ref_fp);
            usize::from(cache.contains_metrics(key))
        } else {
            count_cached(u, graph, instances, cache, base, step)
        };
        u.task_cost = u.task_cost.saturating_sub(pruned);
        pruned_total += pruned;
    }
    plan.cached_tasks = pruned_total;
    pruned_total
}

/// Build a unit's fine-grain merge input: one [`MergeStage`] per bundled
/// compact node, in unit order. The executor (`coordinator/exec.rs`) and
/// the planning probes below all build their [`ReuseTree`]s from THIS
/// function, so predicted and executed trees cannot drift.
pub fn unit_stages(
    unit: &ScheduleUnit,
    graph: &CompactGraph,
    instances: &[StageInstance],
) -> Vec<MergeStage> {
    unit.nodes
        .iter()
        .enumerate()
        .map(|(i, &n)| MergeStage::new(i, instances[graph.nodes[n].rep].task_path()))
        .collect()
}

/// Probe a unit's reuse tree for already-cached task states, counting
/// task nodes whose content chain key is present.
///
/// This mirrors the executor *by construction*: both sides traverse
/// [`ReuseTree::walk`] and chain keys with [`ReuseTree::chain_keys`]
/// over the same level→task resolution, so predicted reuse cannot drift
/// from measured reuse.
fn count_cached(
    unit: &ScheduleUnit,
    graph: &CompactGraph,
    instances: &[StageInstance],
    cache: &ReuseCache,
    base: Key,
    step: f64,
) -> usize {
    let stages = unit_stages(unit, graph, instances);
    let tree = ReuseTree::build(&stages);
    let levels = tree.walk();
    let keys = tree.chain_keys(&levels, base, |level, member| {
        task_cache_sig(&instances[graph.nodes[unit.nodes[member]].rep].tasks[level - 1], step)
    });
    levels
        .iter()
        .flatten()
        .filter(|n| n.stage.is_none() && cache.contains_state(keys[n.node]))
        .count()
}

/// Kernel launches a unit needs under frontier batching with width
/// `width`: the executor walks the unit's reuse tree level by level and
/// issues `ceil(level_task_nodes / width)` batched calls per level.
/// Units with empty task paths cost one launch. Comparison units come
/// out as one launch because the parameterless `cmp` task collapses to
/// a single tree node; a parameterized compare task would need explicit
/// handling here (the executor always issues one compare per unit).
pub fn unit_launch_count(
    unit: &ScheduleUnit,
    graph: &CompactGraph,
    instances: &[StageInstance],
    width: usize,
) -> usize {
    let width = width.max(1);
    let stages = unit_stages(unit, graph, instances);
    if stages.first().map(|s| s.path.is_empty()).unwrap_or(true) {
        return 1;
    }
    let tree = ReuseTree::build(&stages);
    tree.walk()
        .iter()
        .map(|level| {
            let tasks = level.iter().filter(|n| n.stage.is_none()).count();
            tasks.div_ceil(width)
        })
        .sum()
}

/// The batched-unit cost model: one fixed `launch_cost` per kernel
/// launch plus `marginal` seconds per task executed — the linear
/// launch-overhead model behind fine-grain task merging (a batch of B
/// same-task evaluations costs `launch + B·marginal`, not `B·(launch +
/// marginal)`). Feed `launches` from [`unit_launch_count`] and `tasks`
/// from [`ScheduleUnit::task_cost`].
pub fn batched_unit_cost(launches: usize, tasks: usize, launch_cost: f64, marginal: f64) -> f64 {
    launches as f64 * launch_cost + tasks as f64 * marginal
}

/// Default per-launch overhead (seconds) for [`batched_unit_cost`]
/// pricing when no measured model is available — what LPT dispatch
/// (`coordinator/cluster.rs`) and the DES simulator's batching model
/// (`simulate/des.rs`) charge per kernel launch. Only the *ratio*
/// against [`DEFAULT_MARGINAL_COST_SECS`] matters for ordering.
pub const DEFAULT_LAUNCH_COST_SECS: f64 = 0.05;

/// Default marginal per-task cost (seconds) for [`batched_unit_cost`]
/// pricing: on the order of the Table-6 mean task cost (~1 s).
pub const DEFAULT_MARGINAL_COST_SECS: f64 = 1.0;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::default_space;
    use crate::workflow::{instantiate_study, paper_workflow, Evaluation};

    fn study(n: usize, vary: impl Fn(usize, &mut Vec<f64>)) -> (CompactGraph, Vec<StageInstance>) {
        let wf = paper_workflow();
        let space = default_space();
        let evals: Vec<Evaluation> = (0..n)
            .map(|id| {
                let mut params = space.defaults();
                vary(id, &mut params);
                Evaluation { id, tile: 0, params }
            })
            .collect();
        let insts = instantiate_study(&wf, &evals);
        (CompactGraph::build(&insts, true), insts)
    }

    #[test]
    fn stage_level_plan_is_singletons() {
        let (g, insts) = study(8, |id, p| p[5] = 5.0 * (id % 4 + 1) as f64);
        let plan = plan_study(&g, &insts, FineAlgorithm::None);
        plan.assert_valid(&g);
        assert!(plan.units.iter().all(|u| u.kind == UnitKind::Single));
        assert_eq!(plan.fine.tasks_merged, plan.fine.tasks_replica);
        assert_eq!(plan.fine_reuse(), 0.0);
        // 4 distinct G1 values -> 1 norm + 4 seg + 4 cmp units
        assert_eq!(plan.units.len(), 9);
        assert_eq!(plan.coarse_saved, 24 - 9);
    }

    #[test]
    fn rtma_plan_merges_shared_prefixes() {
        // t5's parameter varies -> t1..t4 shared among all evals
        let (g, insts) = study(6, |id, p| p[9] = 5.0 * (id + 1) as f64);
        let plan = plan_study(&g, &insts, FineAlgorithm::Rtma(6));
        plan.assert_valid(&g);
        let merged: Vec<_> =
            plan.units.iter().filter(|u| u.kind == UnitKind::Merged).collect();
        assert_eq!(merged.len(), 1, "one segmentation bucket: {:?}", plan.units);
        // 6 stages x 7 tasks = 42 replica; shared t1..t4 once: 4 + 6*3 = 22
        assert_eq!(merged[0].task_cost, 22);
        assert!(plan.fine_reuse() > 0.0);
    }

    #[test]
    fn deps_follow_the_workflow_chain() {
        let (g, insts) = study(5, |id, p| p[6] = 2.0 * (id + 1) as f64);
        let plan = plan_study(&g, &insts, FineAlgorithm::Rtma(3));
        plan.assert_valid(&g);
        for u in &plan.units {
            match u.stage_idx {
                0 => assert!(u.deps.is_empty()),
                _ => {
                    assert!(!u.deps.is_empty());
                    for &d in &u.deps {
                        assert_eq!(plan.units[d].stage_idx, u.stage_idx - 1);
                    }
                }
            }
        }
    }

    #[test]
    fn different_tiles_never_merge() {
        let wf = paper_workflow();
        let space = default_space();
        let evals: Vec<Evaluation> = (0..4)
            .map(|id| Evaluation { id, tile: (id % 2) as u64, params: space.defaults() })
            .collect();
        let insts = instantiate_study(&wf, &evals);
        let g = CompactGraph::build(&insts, true);
        let plan = plan_study(&g, &insts, FineAlgorithm::Rtma(4));
        plan.assert_valid(&g);
        for u in &plan.units {
            let sig = insts[g.nodes[u.nodes[0]].rep].input_sig;
            for &n in &u.nodes {
                assert_eq!(insts[g.nodes[n].rep].input_sig, sig);
            }
        }
    }

    #[test]
    fn trtma_respects_bucket_target() {
        let (g, insts) = study(12, |id, p| {
            p[5] = 5.0 * (id % 3 + 1) as f64;
            p[9] = 5.0 * (id + 1) as f64;
        });
        let plan = plan_study(&g, &insts, FineAlgorithm::Trtma(TrtmaOptions::new(4)));
        plan.assert_valid(&g);
        let seg_units = plan.units_of_stage(1);
        assert!(seg_units.len() <= 4, "seg units: {}", seg_units.len());
    }

    #[test]
    fn all_algorithms_agree_on_task_totals_invariant() {
        let (g, insts) = study(10, |id, p| {
            p[5] = 5.0 * (id % 5 + 1) as f64;
        });
        let replica: usize = g.nodes.iter().map(|n| insts[n.rep].tasks.len()).sum();
        for algo in [
            FineAlgorithm::None,
            FineAlgorithm::Naive(4),
            FineAlgorithm::Sca(4),
            FineAlgorithm::Rtma(4),
            FineAlgorithm::Trtma(TrtmaOptions::new(4)),
        ] {
            let plan = plan_study(&g, &insts, algo);
            plan.assert_valid(&g);
            assert_eq!(plan.fine.tasks_replica, replica, "{}", algo.name());
            assert!(plan.fine.tasks_merged <= replica, "{}", algo.name());
            assert_eq!(plan.tasks_to_execute(), plan.fine.tasks_merged);
        }
    }

    #[test]
    fn merge_time_is_recorded_for_fine_algorithms() {
        let (g, insts) = study(30, |id, p| p[9] = 5.0 * (id % 16 + 1) as f64);
        let plan = plan_study(&g, &insts, FineAlgorithm::Sca(5));
        assert!(plan.merge_time > Duration::ZERO);
    }

    #[test]
    fn launch_counts_follow_the_frontier_shape() {
        // t5 varies -> shared t1..t4 prefix, fan-out below
        let (g, insts) = study(6, |id, p| p[9] = 5.0 * (id + 1) as f64);
        let plan = plan_study(&g, &insts, FineAlgorithm::Rtma(6));
        let merged = plan
            .units
            .iter()
            .find(|u| u.kind == UnitKind::Merged)
            .expect("one merged segmentation bucket");
        // width 1 = node-at-a-time: one launch per unique task
        assert_eq!(unit_launch_count(merged, &g, &insts, 1), merged.task_cost);
        // unbounded width: one launch per tree level
        let levels = insts[g.nodes[merged.nodes[0]].rep].tasks.len();
        assert_eq!(unit_launch_count(merged, &g, &insts, usize::MAX), levels);
        // widths in between are monotone
        let (l1, l4, l16) = (
            unit_launch_count(merged, &g, &insts, 1),
            unit_launch_count(merged, &g, &insts, 4),
            unit_launch_count(merged, &g, &insts, 16),
        );
        assert!(l1 >= l4 && l4 >= l16 && l16 >= levels);
        // comparison units cost one launch regardless of width
        let cmp = plan.units.iter().find(|u| u.stage_idx == 2).expect("compare unit");
        assert_eq!(unit_launch_count(cmp, &g, &insts, 1), 1);
    }

    #[test]
    fn batched_cost_is_launches_plus_marginal() {
        let c = batched_unit_cost(3, 24, 0.5, 0.125);
        assert!((c - (3.0 * 0.5 + 24.0 * 0.125)).abs() < 1e-12);
        // batching B same-task evaluations beats B separate launches
        let unbatched = batched_unit_cost(24, 24, 0.5, 0.125);
        assert!(c < unbatched);
    }
}
