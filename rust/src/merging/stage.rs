//! Stage-level (coarse-grain) merging — paper Algorithm 1.
//!
//! Builds the *compact graph*: one node per **unique** stage instance
//! (same stage, same input, same parameters ⇒ same output), with the
//! replica workflows' edges preserved. The `find` step uses a hash map,
//! so inserting n workflow instances of k stages is O(kn) (the paper's
//! optimized bound).

use std::collections::HashMap;

use crate::workflow::StageInstance;

/// One unique stage instance in the compact graph.
#[derive(Clone, Debug)]
pub struct CompactNode {
    /// Index of this node in [`CompactGraph::nodes`].
    pub id: usize,
    /// Representative stage instance (first one merged into this node).
    pub rep: usize,
    /// All stage-instance ids this node covers (≥ 1; > 1 means coarse
    /// reuse happened).
    pub covered: Vec<usize>,
    /// Upstream compact node (None for first stage of the chain).
    pub parent: Option<usize>,
    /// Downstream compact nodes.
    pub children: Vec<usize>,
    pub stage: String,
    pub stage_idx: usize,
}

/// The compact (deduplicated) workflow graph of a whole study.
#[derive(Clone, Debug, Default)]
pub struct CompactGraph {
    pub nodes: Vec<CompactNode>,
    /// For each evaluation: the compact node executing each stage level.
    pub eval_nodes: HashMap<usize, Vec<usize>>,
}

impl CompactGraph {
    /// Algorithm 1, with the hash-table `find`. When `dedupe` is false the
    /// graph is the replica-based composition ("No reuse" baseline).
    pub fn build(instances: &[StageInstance], dedupe: bool) -> Self {
        let mut nodes: Vec<CompactNode> = Vec::new();
        // PendingVer of Algorithm 1: full_sig -> node id
        let mut by_sig: HashMap<(usize, u64), usize> = HashMap::new();
        let mut eval_nodes: HashMap<usize, Vec<usize>> = HashMap::new();

        for inst in instances {
            let key = (inst.stage_idx, inst.full_sig);
            let node_id = match by_sig.get(&key) {
                Some(&id) if dedupe => {
                    nodes[id].covered.push(inst.id);
                    id
                }
                _ => {
                    let id = nodes.len();
                    // parent: the node executing this eval's previous stage
                    let parent = if inst.stage_idx == 0 {
                        None
                    } else {
                        eval_nodes.get(&inst.eval).and_then(|v| v.last().copied())
                    };
                    nodes.push(CompactNode {
                        id,
                        rep: inst.id,
                        covered: vec![inst.id],
                        parent,
                        children: Vec::new(),
                        stage: inst.stage.clone(),
                        stage_idx: inst.stage_idx,
                    });
                    if let Some(p) = parent {
                        nodes[p].children.push(id);
                    }
                    by_sig.insert(key, id);
                    id
                }
            };
            eval_nodes.entry(inst.eval).or_default().push(node_id);
        }
        CompactGraph { nodes, eval_nodes }
    }

    /// Unique stage instances remaining per stage index.
    pub fn nodes_of_stage(&self, stage_idx: usize) -> Vec<usize> {
        self.nodes.iter().filter(|n| n.stage_idx == stage_idx).map(|n| n.id).collect()
    }

    /// Total stage instances before merging.
    pub fn replica_stage_count(&self) -> usize {
        self.nodes.iter().map(|n| n.covered.len()).sum()
    }

    /// Stage instances removed by coarse-grain reuse.
    pub fn stages_saved(&self) -> usize {
        self.replica_stage_count() - self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::default_space;
    use crate::workflow::{instantiate_study, paper_workflow, Evaluation};

    fn study(n: usize, vary: impl Fn(usize, &mut Vec<f64>)) -> Vec<StageInstance> {
        let wf = paper_workflow();
        let space = default_space();
        let evals: Vec<Evaluation> = (0..n)
            .map(|id| {
                let mut params = space.defaults();
                vary(id, &mut params);
                Evaluation { id, tile: 0, params }
            })
            .collect();
        instantiate_study(&wf, &evals)
    }

    #[test]
    fn normalization_collapses_to_one_node() {
        // each eval varies G1 -> segmentation/comparison unique, norm shared
        let insts = study(10, |id, p| p[5] = 5.0 * (id + 1) as f64);
        let g = CompactGraph::build(&insts, true);
        assert_eq!(g.nodes_of_stage(0).len(), 1);
        assert_eq!(g.nodes_of_stage(1).len(), 10);
        assert_eq!(g.nodes_of_stage(2).len(), 10);
        assert_eq!(g.replica_stage_count(), 30);
        assert_eq!(g.stages_saved(), 9);
    }

    #[test]
    fn identical_evaluations_collapse_fully() {
        let insts = study(5, |_, _| {});
        let g = CompactGraph::build(&insts, true);
        assert_eq!(g.nodes.len(), 3); // one node per stage
        assert_eq!(g.stages_saved(), 12);
        // all evals point at the same chain
        for v in g.eval_nodes.values() {
            assert_eq!(v, g.eval_nodes.get(&0).unwrap());
        }
    }

    #[test]
    fn no_dedupe_keeps_replicas() {
        let insts = study(4, |_, _| {});
        let g = CompactGraph::build(&insts, false);
        assert_eq!(g.nodes.len(), 12);
        assert_eq!(g.stages_saved(), 0);
    }

    #[test]
    fn parent_chain_is_consistent() {
        let insts = study(6, |id, p| p[6] = 2.0 * (id % 3 + 1) as f64);
        let g = CompactGraph::build(&insts, true);
        for n in &g.nodes {
            match n.stage_idx {
                0 => assert!(n.parent.is_none()),
                _ => {
                    let p = &g.nodes[n.parent.unwrap()];
                    assert_eq!(p.stage_idx, n.stage_idx - 1);
                    assert!(p.children.contains(&n.id));
                }
            }
        }
        // 3 distinct G2 values -> 3 unique segmentation nodes
        assert_eq!(g.nodes_of_stage(1).len(), 3);
    }

    #[test]
    fn fig6_compact_graph() {
        // Fig. 6 of the paper: 3 parameter sets over tasks A,B,C,D where
        // sets share (A,B) and sets 1,3 share (A,B,C): 12 replica tasks
        // -> 7 compact tasks. Modeled as a 4-stage workflow with one task
        // per stage.
        use crate::workflow::{StageSpec, TaskSpec, WorkflowSpec};
        let wf = WorkflowSpec::new(
            "fig6",
            vec![
                StageSpec::new("A", vec![TaskSpec::new("A", "x::a", vec![0])]),
                StageSpec::new("B", vec![TaskSpec::new("B", "x::b", vec![1])]),
                StageSpec::new("C", vec![TaskSpec::new("C", "x::c", vec![2])]),
                StageSpec::new("D", vec![TaskSpec::new("D", "x::d", vec![3])]),
            ],
        );
        // params: (1,5,9,13), (1,5,2,7), (1,5,9,15) — paper's Set 1..3
        let sets = [[1.0, 5.0, 9.0, 13.0], [1.0, 5.0, 2.0, 7.0], [1.0, 5.0, 9.0, 15.0]];
        let evals: Vec<Evaluation> = sets
            .iter()
            .enumerate()
            .map(|(id, p)| Evaluation { id, tile: 0, params: p.to_vec() })
            .collect();
        let g = CompactGraph::build(&instantiate_study(&wf, &evals), true);
        assert_eq!(g.replica_stage_count(), 12);
        assert_eq!(g.nodes.len(), 7, "paper: 12 tasks -> 7 tasks (~41% fewer)");
    }
}
