//! Paper Fig. 24: two buckets with the *same task count* but different
//! reuse-tree topologies have different execution costs — the imbalance
//! source the task-count-balanced TRTMA cannot see (§4.5.1).
//!
//! Bucket 1: three stages with maximal reuse (t1..t6 shared, three t7
//! leaves). Bucket 2: two stages sharing t1..t5 (two t6, two t7). Both
//! hold 9 task executions; with the paper's Table-6 costs the second is
//! ~1.25× more expensive because t6 (the dominant task) runs twice.

use rtf_reuse::benchx::{fmt_secs, Table};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{prepare, run_sim};
use rtf_reuse::merging::{unique_tasks, Bucket, FineAlgorithm, MergeStage, TrtmaOptions};
use rtf_reuse::simulate::{default_cost_model, SimOptions};

fn bucket_cost(paths: &[Vec<u64>], names: &[&str], model: &rtf_reuse::simulate::CostModel) -> f64 {
    // cost = Σ over distinct path prefixes of the level's task cost
    let mut seen = std::collections::HashSet::new();
    let mut total = 0.0;
    for p in paths {
        let mut acc: u64 = 0xcbf29ce484222325;
        for (level, &sig) in p.iter().enumerate() {
            acc = acc.wrapping_mul(0x100000001b3) ^ sig;
            if seen.insert((level, acc)) {
                total += model.cost_of(names[level]);
            }
        }
    }
    total
}

fn main() {
    let model = default_cost_model();
    let names = ["t1", "t2", "t3", "t4", "t5", "t6", "t7"];

    // Fig. 24a: bucket 1 = 3 stages, t1..t6 shared; bucket 2 = 2 stages,
    // t1..t5 shared (t6 splits).
    let b1: Vec<Vec<u64>> = vec![
        vec![1, 2, 3, 4, 5, 6, 70],
        vec![1, 2, 3, 4, 5, 6, 71],
        vec![1, 2, 3, 4, 5, 6, 72],
    ];
    let b2: Vec<Vec<u64>> =
        vec![vec![1, 2, 3, 4, 5, 60, 73], vec![1, 2, 3, 4, 5, 61, 74]];

    // both buckets execute the same number of unique tasks
    let stages1: Vec<MergeStage> =
        b1.iter().cloned().enumerate().map(|(i, p)| MergeStage::new(i, p)).collect();
    let stages2: Vec<MergeStage> =
        b2.iter().cloned().enumerate().map(|(i, p)| MergeStage::new(i, p)).collect();
    let n1 = unique_tasks(&stages1, &[0, 1, 2]);
    let n2 = unique_tasks(&stages2, &[0, 1]);
    assert_eq!(n1, 9);
    assert_eq!(n2, 9);

    let c1 = bucket_cost(&b1, &names, &model);
    let c2 = bucket_cost(&b2, &names, &model);
    let mut t = Table::new(&["bucket", "stages", "unique tasks", "cost", "normalized"]);
    t.row(&[
        "1 (deep reuse)".into(),
        "3".into(),
        n1.to_string(),
        fmt_secs(c1),
        format!("{:.2}", c1 / c1),
    ]);
    t.row(&[
        "2 (t6 splits)".into(),
        "2".into(),
        n2.to_string(),
        fmt_secs(c2),
        format!("{:.2}", c2 / c1),
    ]);
    t.print("Fig. 24 — equal task count, unequal cost (paper: bucket 2 ~1.25x slower)");
    println!(
        "cost ratio bucket2/bucket1 = {:.3} (paper: 1.48/1.18 = 1.254)",
        c2 / c1
    );

    // End-to-end: the same effect degrades TRTMA's balance under
    // variable task costs — quantified via the simulator's cv knob.
    let r = 31; // sample 496
    let cfg = StudyConfig {
        method: SaMethod::Moat { r },
        algorithm: FineAlgorithm::Trtma(TrtmaOptions::new(3 * 16)),
        workers: 16,
        ..StudyConfig::default()
    };
    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);
    let mut t2 = Table::new(&["cost model", "makespan", "utilization %"]);
    for (label, cv) in [("uniform per task", 0.0), ("variable (cv=0.3)", 0.3)] {
        let opts = SimOptions::new(16).with_cv(cv, 7);
        let rep = run_sim(&prepared, &plan, &model, &opts);
        t2.row(&[
            label.to_string(),
            fmt_secs(rep.makespan),
            format!("{:.1}", rep.utilization() * 100.0),
        ]);
    }
    t2.print("topology/cost imbalance effect on a TRTMA-balanced plan");

    let _ = Bucket::of(vec![0]); // keep the type exercised in the bench build
}
