//! Self-healing overhead benchmark: what a flapping peer link costs the
//! node that rides the cluster fabric.
//!
//! Phase 1 runs a two-node loopback cluster fault-free: cold study on
//! node A, warm study on node B (served over the fabric) — the
//! baseline. Phase 2 reruns the identical cluster with a scripted flap
//! on node B's peer link: bursts of four consecutive refused calls,
//! each long enough to trip the circuit breaker (threshold 3), spaced
//! so the cooldown elapses and the half-open probe closes it again.
//! Node B must degrade to local launches during each burst and return
//! to the fabric after it — completing with bit-identical results.
//!
//! Acceptance: the flapped warm run keeps at least 0.7x the fault-free
//! throughput (asserted in full mode; `--test` CI smoke asserts the
//! correctness properties only, since shared-runner wall clocks are too
//! noisy to gate on). Writes `BENCH_chaos.json`.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use rtf_reuse::benchx::fmt_secs;
use rtf_reuse::cache::CacheConfig;
use rtf_reuse::faults::{FaultPlan, Faults, PeerFault};
use rtf_reuse::serve::{run_jobs, JobSpec, ServeOptions, ServiceReport, StudyService, WireServer};

fn reserve_addr() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("reserve a port");
    listener.local_addr().expect("reserved addr").to_string()
}

fn opts(peers: &[String], own: &str, faults: Faults) -> ServeOptions {
    ServeOptions {
        service_workers: 1,
        study_workers: 2,
        cache: CacheConfig { capacity_bytes: 512 * 1024 * 1024, ..CacheConfig::default() },
        peers: peers.to_vec(),
        cluster_addr: Some(own.to_string()),
        faults,
        ..ServeOptions::default()
    }
}

fn spawn_node(opts: ServeOptions, addr: &str) -> thread::JoinHandle<ServiceReport> {
    let svc = StudyService::start(opts).expect("node starts");
    let server = WireServer::bind(svc, addr).expect("node binds");
    thread::spawn(move || server.run().expect("node drains cleanly"))
}

/// Bursts of four consecutive refusals every 16 peer calls, scripted
/// over the first `calls` ordinals. Four consecutive failures trip the
/// breaker (threshold 3) mid-burst; the 12-call gap gives the cooldown
/// time to elapse so the half-open probe closes it before the next
/// burst — a flapping link, not a dead one.
fn flap_plan(calls: u64) -> FaultPlan {
    let mut plan = FaultPlan::new();
    let mut n = 4;
    while n + 3 < calls {
        for i in 0..4 {
            plan = plan.peer_fault(n + i, PeerFault::Refuse);
        }
        n += 16;
    }
    plan
}

/// One cluster round: cold study on A, timed warm study on B, drain.
/// Returns (warm job y, warm launches, warm wall seconds, B's report).
fn run_round(
    args: &[String],
    faults_b: Faults,
) -> (Vec<f64>, u64, f64, ServiceReport) {
    let addr_a = reserve_addr();
    let addr_b = reserve_addr();
    let peers = vec![addr_a.clone(), addr_b.clone()];
    let node_a = spawn_node(opts(&peers, &addr_a, Faults::none()), &addr_a);
    let node_b = spawn_node(opts(&peers, &addr_b, faults_b), &addr_b);

    let spec = |tenant: &str| JobSpec { tenant: tenant.into(), args: args.to_vec(), tune: false };
    run_jobs(&addr_a, &[spec("cold")], false).expect("cold run on node A");
    let t0 = Instant::now();
    run_jobs(&addr_b, &[spec("warm")], false).expect("warm run on node B");
    let wall = t0.elapsed().as_secs_f64();

    run_jobs(&addr_b, &[], true).expect("drain B");
    run_jobs(&addr_a, &[], true).expect("drain A");
    node_a.join().expect("node A joins");
    let report_b = node_b.join().expect("node B joins");
    let warm = report_b.jobs.iter().find(|j| j.tenant == "warm").expect("warm job billed");
    assert!(warm.ok(), "warm job failed: {:?}", warm.error);
    (warm.y.clone(), warm.launches, wall, report_b)
}

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    let args: Vec<String> =
        vec!["method=moat".into(), format!("r={}", if test_mode { 1 } else { 2 })];

    // phase 1: the fault-free fabric baseline
    let (base_y, base_launches, base_wall, base_report) = run_round(&args, Faults::none());

    // phase 2: the same cluster, node B's peer link flapping
    let plan = Arc::new(flap_plan(400));
    let (flap_y, flap_launches, flap_wall, flap_report) =
        run_round(&args, Faults::hooked(plan.clone()));

    // self-healing must never change results, and the flap must have
    // actually fired (the plan exercised the breaker, not thin air)
    assert_eq!(base_y, flap_y, "flapped run is bit-identical to the fault-free run");
    let fired = plan.fired().peer_faults;
    assert!(fired >= 4, "at least one full burst fired (got {fired})");
    assert!(
        flap_launches >= base_launches,
        "a flapping fabric cannot reduce launches: {flap_launches} < {base_launches}"
    );

    let evals = flap_report.jobs[0].n_evals;
    let ratio = base_wall / flap_wall.max(1e-9);
    println!(
        "fault-free warm run: {base_launches} launches in {} | flapped: {flap_launches} \
         launches in {} ({fired} scripted refusals) | throughput ratio {ratio:.3}",
        fmt_secs(base_wall),
        fmt_secs(flap_wall),
    );

    let json = format!(
        "{{\n  \"bench\": \"chaos_recovery\",\n  \"mode\": \"{}\",\n  \"evals\": {evals},\n  \
         \"fault_free_launches\": {base_launches},\n  \"flapped_launches\": {flap_launches},\n  \
         \"peer_faults_fired\": {fired},\n  \"fault_free_wall_secs\": {base_wall:.6},\n  \
         \"flapped_wall_secs\": {flap_wall:.6},\n  \"throughput_ratio\": {ratio:.6}\n}}\n",
        if test_mode { "test" } else { "full" },
    );
    std::fs::write("BENCH_chaos.json", &json).expect("write BENCH_chaos.json");
    println!("wrote BENCH_chaos.json");

    let _ = base_report;
    println!(
        "ACCEPTANCE: flapped throughput is {ratio:.3}x fault-free (floor 0.7 in full mode) — {}",
        if ratio >= 0.7 || test_mode { "PASS" } else { "FAIL" }
    );
    if !test_mode {
        assert!(
            ratio >= 0.7,
            "peer flap degraded throughput below the 0.7x floor: {ratio:.3}"
        );
    }
}
