//! Paper Fig. 19: MOAT study execution time vs sample size for the five
//! application versions (No reuse / Stage level / Naïve / SCA / RTMA).
//!
//! Makespans come from the discrete-event cluster simulator (6 workers,
//! the paper's "6 Stampede nodes"; WP are serial stage slots); the merge-analysis times
//! are measured for real — they are the paper's contribution and the
//! quantity Fig. 19 stacks on top of the bars. Expected shape: every
//! reuse version beats NR; Naïve barely improves on Stage; SCA's merge
//! time grows to a visible fraction of the run; RTMA matches SCA's reuse
//! at negligible merge cost (speedup up to ~2.6× over NR).

use rtf_reuse::benchx::{fmt_secs, Table};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{prepare, run_sim};
use rtf_reuse::merging::FineAlgorithm;
use rtf_reuse::simulate::{default_cost_model, SimOptions};

fn main() {
    let model = default_cost_model();
    let workers = 6;
    let mut t = Table::new(&[
        "sample", "version", "makespan", "merge", "reuse %", "speedup vs NR",
    ]);

    for sample in [160usize, 320, 480, 640] {
        let r = sample / 16;
        let mut nr_makespan = None;
        for (name, coarse, algo) in [
            ("no reuse", false, FineAlgorithm::None),
            ("stage level", true, FineAlgorithm::None),
            ("naive", true, FineAlgorithm::Naive(7)),
            ("sca", true, FineAlgorithm::Sca(7)),
            ("rtma", true, FineAlgorithm::Rtma(7)),
        ] {
            let cfg = StudyConfig {
                method: SaMethod::Moat { r },
                coarse,
                algorithm: algo,
                workers,
                ..StudyConfig::default()
            };
            let prepared = prepare(&cfg);
            let plan = prepared.plan(&cfg); // merge time measured inside
            let opts = SimOptions::new(workers);
            let rep = run_sim(&prepared, &plan, &model, &opts);
            let total = rep.makespan + plan.merge_time.as_secs_f64();
            if nr_makespan.is_none() {
                nr_makespan = Some(total);
            }
            t.row(&[
                sample.to_string(),
                name.to_string(),
                fmt_secs(rep.makespan),
                fmt_secs(plan.merge_time.as_secs_f64()),
                format!("{:.1}", plan.fine_reuse() * 100.0),
                format!("{:.2}x", nr_makespan.unwrap() / total),
            ]);
        }
    }
    t.print("Fig. 19 — MOAT study, 6 workers (sim makespan + real merge time)");
}
