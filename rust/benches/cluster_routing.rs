//! Cluster phase-2 benchmark: what front-door routing costs and what
//! hot-prefix replication saves.
//!
//! Phase 1 boots a three-node route-enabled cluster, warms the
//! predicted owner, then times K warm submits sent DIRECTLY to the
//! owner against K warm submits sent through a non-owner's front door
//! (each routed over a dedicated peer hop and proxied back).
//! Acceptance: routed throughput is at least 0.8× direct — the front
//! door must cost a hop, not a rerun.
//!
//! Phase 2 runs the replication drill twice on a four-node ring: warm
//! the cluster past the hot watermark, kill the shard owner, then probe
//! from a node that never executed the study. With `replicas=1` the
//! orphaned shard is served from ring replicas; with `replicas=0` it is
//! relaunched locally behind the open breaker. Acceptance: the
//! replica-served probe launches strictly less and its throughput is at
//! least 0.8× of — in practice well above — the breaker-open baseline.
//! Counts are asserted in `--test` (CI smoke) mode too. Writes
//! `BENCH_routing.json`.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use rtf_reuse::benchx::fmt_secs;
use rtf_reuse::cache::CacheConfig;
use rtf_reuse::config::StudyConfig;
use rtf_reuse::serve::{run_jobs, JobSpec, ServeOptions, ServiceReport, StudyService, WireServer};

/// Proxy handles live at/above `server::ROUTE_BASE`; an id past this
/// mark proves the submit was routed.
const ROUTE_BASE: u64 = 1 << 32;

fn reserve_addr() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("reserve a port");
    listener.local_addr().expect("reserved addr").to_string()
}

fn opts(peers: &[String], own: &str, route: bool, replicas: usize) -> ServeOptions {
    ServeOptions {
        service_workers: 1,
        study_workers: 2,
        cache: CacheConfig { capacity_bytes: 512 * 1024 * 1024, ..CacheConfig::default() },
        peers: peers.to_vec(),
        cluster_addr: Some(own.to_string()),
        route,
        replicas,
        ..ServeOptions::default()
    }
}

fn spawn_node(
    opts: ServeOptions,
    addr: &str,
) -> (Arc<StudyService>, thread::JoinHandle<ServiceReport>) {
    let svc = StudyService::start(opts).expect("node starts");
    let server = WireServer::bind(svc, addr).expect("node binds");
    let svc = Arc::clone(server.service());
    (svc, thread::spawn(move || server.run().expect("node drains cleanly")))
}

fn assert_scoped_sums(report: &ServiceReport, node: &str) {
    let sums = report.scoped_totals();
    assert_eq!(sums.hits, report.cache.hits, "{node}: scoped hits");
    assert_eq!(sums.remote_hits, report.cache.remote_hits, "{node}: scoped remote hits");
    assert_eq!(sums.misses, report.cache.misses, "{node}: scoped misses");
    assert_eq!(sums.inserts, report.cache.inserts, "{node}: scoped inserts");
}

/// One replication drill: four nodes, warm-up past the hot watermark,
/// owner killed, probe from the idle fourth node. Returns the probe's
/// (launches, wall seconds, remote hits, y).
fn replication_drill(
    args: &[String],
    replicas: usize,
) -> (u64, f64, u64, Vec<f64>) {
    let addrs: Vec<String> = (0..4).map(|_| reserve_addr()).collect();
    let mut nodes: Vec<_> = addrs
        .iter()
        .map(|a| Some(spawn_node(opts(&addrs, a, false, replicas), a)))
        .collect();

    let spec = |tenant: &str| JobSpec { tenant: tenant.into(), args: args.to_vec(), tune: false };
    for (i, tenant) in ["cold", "warm1", "warm2"].iter().enumerate() {
        let out = run_jobs(&addrs[i], &[spec(tenant)], false).expect("warm-up job");
        assert!(out.jobs[0].ok(), "warm-up {i}: {:?}", out.jobs[0].error);
    }

    // kill the first node: its shard is now orphaned — replicated or not
    let (svc0, handle0) = nodes[0].take().expect("owner node");
    run_jobs(&addrs[0], &[], true).expect("drain owner");
    handle0.join().expect("owner joins");
    drop(svc0);

    let t0 = Instant::now();
    let out = run_jobs(&addrs[3], &[spec("probe")], false).expect("probe job");
    let wall = t0.elapsed().as_secs_f64();
    assert!(out.jobs[0].ok(), "probe: {:?}", out.jobs[0].error);

    let mut probe_remote_hits = 0;
    for i in (1..4).rev() {
        let (svc, handle) = nodes[i].take().expect("node");
        run_jobs(&addrs[i], &[], true).expect("drain node");
        let report = handle.join().expect("node joins");
        assert_scoped_sums(&report, &format!("drill node {i}"));
        if i == 3 {
            probe_remote_hits = report.cache.remote_hits;
        }
        drop(svc);
    }
    (out.jobs[0].launches, wall, probe_remote_hits, out.jobs[0].y.clone())
}

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    let args: Vec<String> =
        vec!["method=moat".into(), format!("r={}", if test_mode { 1 } else { 2 })];
    let repeats = if test_mode { 3 } else { 8 };
    let spec = |tenant: &str| JobSpec { tenant: tenant.into(), args: args.clone(), tune: false };

    // ---- phase 1: front-door routing overhead --------------------------
    let addrs: Vec<String> = (0..3).map(|_| reserve_addr()).collect();
    let nodes: Vec<_> =
        addrs.iter().map(|a| spawn_node(opts(&addrs, a, true, 1), a)).collect();

    // the planner probe names the peer owning the study's key plurality
    let cfg = StudyConfig::from_args(&args).expect("study parses");
    let winner = match nodes[0].0.predict_route(&cfg) {
        None => 0,
        Some(addr) => addrs.iter().position(|a| *a == addr).expect("winner is a member"),
    };
    let router = (winner + 1) % addrs.len();

    // warm the owner so both timed phases measure serving, not compute
    let cold = run_jobs(&addrs[winner], &[spec("cold")], false).expect("cold run");
    assert!(cold.jobs[0].ok(), "cold job: {:?}", cold.jobs[0].error);
    let base_y = cold.jobs[0].y.clone();

    let t0 = Instant::now();
    for i in 0..repeats {
        let out =
            run_jobs(&addrs[winner], &[spec(&format!("direct{i}"))], false).expect("direct run");
        assert!(out.jobs[0].ok(), "direct job {i}: {:?}", out.jobs[0].error);
        assert_eq!(out.jobs[0].y, base_y, "direct job {i} matches the cold run");
    }
    let wall_direct = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    for i in 0..repeats {
        let out =
            run_jobs(&addrs[router], &[spec(&format!("routed{i}"))], false).expect("routed run");
        assert!(out.jobs[0].ok(), "routed job {i}: {:?}", out.jobs[0].error);
        assert_eq!(out.jobs[0].y, base_y, "routed job {i} matches the cold run");
        assert!(
            out.jobs[0].job >= ROUTE_BASE,
            "routed job {i} got local id {} — the front door did not route it",
            out.jobs[0].job
        );
    }
    let wall_routed = t0.elapsed().as_secs_f64();
    let routed_ratio = wall_direct / wall_routed;

    for i in [router, (winner + 2) % addrs.len(), winner] {
        run_jobs(&addrs[i], &[], true).expect("drain node");
    }
    for (svc, handle) in nodes {
        let report = handle.join().expect("node joins");
        assert_scoped_sums(&report, "routing node");
        drop(svc);
    }

    println!(
        "front door: {repeats} direct submits in {} vs {repeats} routed in {} \
         (routed throughput {routed_ratio:.2}x direct)",
        fmt_secs(wall_direct),
        fmt_secs(wall_routed),
    );

    // ---- phase 2: replica-served vs breaker-open relaunch --------------
    let (launches_rep, wall_rep, remote_hits_rep, y_rep) = replication_drill(&args, 1);
    let (launches_raw, wall_raw, _, y_raw) = replication_drill(&args, 0);
    assert_eq!(y_rep, base_y, "replica-served probe matches the cold run");
    assert_eq!(y_raw, base_y, "breaker-open probe matches the cold run");
    let replica_ratio = wall_raw / wall_rep;

    println!(
        "dead owner: replicas=1 probe {launches_rep} launches in {} \
         ({remote_hits_rep} remote hits) vs replicas=0 probe {launches_raw} launches in {} \
         (replica throughput {replica_ratio:.2}x baseline)",
        fmt_secs(wall_rep),
        fmt_secs(wall_raw),
    );

    let json = format!(
        "{{\n  \"bench\": \"cluster_routing\",\n  \"mode\": \"{}\",\n  \
         \"repeats\": {repeats},\n  \"direct_wall_secs\": {wall_direct:.6},\n  \
         \"routed_wall_secs\": {wall_routed:.6},\n  \
         \"routed_throughput_ratio\": {routed_ratio:.4},\n  \
         \"replica_probe_launches\": {launches_rep},\n  \
         \"replica_probe_wall_secs\": {wall_rep:.6},\n  \
         \"replica_probe_remote_hits\": {remote_hits_rep},\n  \
         \"unreplicated_probe_launches\": {launches_raw},\n  \
         \"unreplicated_probe_wall_secs\": {wall_raw:.6},\n  \
         \"replica_throughput_ratio\": {replica_ratio:.4}\n}}\n",
        if test_mode { "test" } else { "full" },
    );
    std::fs::write("BENCH_routing.json", &json).expect("write BENCH_routing.json");
    println!("wrote BENCH_routing.json");

    println!(
        "ACCEPTANCE: routed {routed_ratio:.2}x direct (floor 0.8), replica-served \
         {launches_rep} launches vs breaker-open {launches_raw}, replica throughput \
         {replica_ratio:.2}x (floor 0.8) — {}",
        if routed_ratio >= 0.8 && launches_rep < launches_raw && replica_ratio >= 0.8 {
            "PASS"
        } else {
            "FAIL"
        }
    );
    assert!(
        routed_ratio >= 0.8,
        "front-door routing must cost a hop, not a rerun: {routed_ratio:.2}x"
    );
    assert!(
        launches_rep < launches_raw,
        "a replica-served probe must relaunch strictly less than the breaker-open \
         baseline: {launches_rep} vs {launches_raw}"
    );
    assert!(remote_hits_rep > 0, "the replica-served probe must show remote hits");
    assert!(
        replica_ratio >= 0.8,
        "replica serving must not be slower than relaunching: {replica_ratio:.2}x"
    );
}
