//! Acceptance benchmark for the multi-tenant study service: N tenants
//! submit the SAME workflow to one service concurrently. Because the
//! service owns a single shared reuse cache with single-flight misses
//! (plus memoized study inputs), the aggregate backend launches across
//! all N tenants must stay within 1.25× of what ONE cold tenant pays —
//! warm tenants ride the shared cache almost entirely.
//!
//! Also asserts the accounting invariant: per-tenant scoped counters
//! sum exactly to the shared cache's global counters, field by field.
//!
//! Unlike the wall-clock benches, the acceptance metric here is a
//! *count* (backend launches), so it is asserted in `--test` (CI smoke)
//! mode too — scheduler noise cannot break it. Writes
//! `BENCH_multi_tenant.json` as the perf-trajectory artifact.

use rtf_reuse::benchx::{fmt_secs, Table};
use rtf_reuse::cache::CacheConfig;
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::merging::FineAlgorithm;
use rtf_reuse::serve::{ServeOptions, ServiceReport, StudyJob, StudyService};

const TENANTS: usize = 4;

fn study(test_mode: bool) -> StudyConfig {
    StudyConfig {
        method: SaMethod::Moat { r: if test_mode { 1 } else { 2 } },
        algorithm: FineAlgorithm::Rtma(7),
        ..StudyConfig::default()
    }
}

fn serve_opts(service_workers: usize) -> ServeOptions {
    ServeOptions {
        service_workers,
        tenant_inflight_cap: 1,
        study_workers: 2,
        cache: CacheConfig { capacity_bytes: 512 * 1024 * 1024, ..CacheConfig::default() },
        ..ServeOptions::default()
    }
}

fn run_service(tenants: usize, service_workers: usize, cfg: &StudyConfig) -> ServiceReport {
    let svc = StudyService::start(serve_opts(service_workers)).expect("service starts");
    for t in 0..tenants {
        svc.submit(StudyJob { tenant: format!("tenant-{t}"), cfg: cfg.clone() })
            .expect("submission accepted");
    }
    svc.drain()
}

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    let cfg = study(test_mode);

    // phase 1: ONE tenant on a fresh service — the cold cost
    let cold = run_service(1, 1, &cfg);
    assert!(cold.jobs.iter().all(|j| j.ok()), "cold job failed: {:?}", cold.jobs);
    let cold_launches = cold.total_launches();

    // phase 2: N tenants concurrently on a fresh service, one shared cache
    let multi = run_service(TENANTS, TENANTS, &cfg);
    assert!(multi.jobs.iter().all(|j| j.ok()), "tenant job failed: {:?}", multi.jobs);
    let total_launches = multi.total_launches();

    // identical workflows must produce identical results for every tenant
    for j in &multi.jobs[1..] {
        assert_eq!(multi.jobs[0].y, j.y, "tenant results diverged");
    }

    // accounting invariant: tenant scopes sum to the shared globals
    let sums = multi.scoped_totals();
    let g = multi.cache;
    assert_eq!(sums.hits, g.hits, "tenant hit counters must sum to global");
    assert_eq!(sums.disk_hits, g.disk_hits);
    assert_eq!(sums.misses, g.misses, "tenant miss counters must sum to global");
    assert_eq!(sums.inserts, g.inserts);
    assert_eq!(sums.metric_hits, g.metric_hits);
    assert_eq!(sums.metric_misses, g.metric_misses);

    let mut t = Table::new(&["phase", "tenants", "launches", "cached", "hits", "wall"]);
    t.row(&[
        "cold (1 tenant)".into(),
        "1".into(),
        cold_launches.to_string(),
        cold.jobs.iter().map(|j| j.cached_tasks).sum::<u64>().to_string(),
        (cold.cache.hits + cold.cache.disk_hits).to_string(),
        fmt_secs(cold.wall.as_secs_f64()),
    ]);
    t.row(&[
        format!("shared ({TENANTS} tenants)"),
        TENANTS.to_string(),
        total_launches.to_string(),
        multi.jobs.iter().map(|j| j.cached_tasks).sum::<u64>().to_string(),
        (g.hits + g.disk_hits).to_string(),
        fmt_secs(multi.wall.as_secs_f64()),
    ]);
    t.print("multi-tenant service: concurrent identical workflows, one shared cache");
    for ten in &multi.tenants {
        println!(
            "  {}: {} launches, {} cache-served, {:.1}% hit rate",
            ten.tenant,
            ten.launches,
            ten.cached_tasks,
            ten.cache.hit_rate() * 100.0
        );
    }

    let ratio = total_launches as f64 / cold_launches as f64;
    let json = format!(
        "{{\n  \"bench\": \"multi_tenant\",\n  \"mode\": \"{}\",\n  \
         \"tenants\": {TENANTS},\n  \"evals_per_tenant\": {},\n  \
         \"cold_launches\": {cold_launches},\n  \"total_launches\": {total_launches},\n  \
         \"launch_ratio\": {ratio:.4},\n  \"input_launches\": {},\n  \
         \"global_hits\": {},\n  \"global_misses\": {},\n  \
         \"cold_wall_secs\": {:.6},\n  \"multi_wall_secs\": {:.6}\n}}\n",
        if test_mode { "test" } else { "full" },
        multi.jobs.first().map(|j| j.n_evals).unwrap_or(0),
        multi.input_launches,
        g.hits + g.disk_hits,
        g.misses,
        cold.wall.as_secs_f64(),
        multi.wall.as_secs_f64(),
    );
    std::fs::write("BENCH_multi_tenant.json", &json).expect("write BENCH_multi_tenant.json");
    println!("wrote BENCH_multi_tenant.json");

    let limit = (cold_launches as f64 * 1.25).ceil() as u64;
    println!(
        "ACCEPTANCE: {TENANTS} tenants spent {total_launches} launches vs cold {cold_launches} \
         ({ratio:.2}x, required <= 1.25x) — {}",
        if total_launches <= limit { "PASS" } else { "FAIL" }
    );
    assert!(
        total_launches <= limit,
        "{TENANTS} concurrent tenants must stay within 1.25x of one cold tenant's launches: \
         {total_launches} > {limit}"
    );
}
