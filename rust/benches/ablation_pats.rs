//! Ablation: PATS (performance-aware task scheduling, paper §2.3) vs
//! FCFS device assignment on hybrid CPU+accelerator worker nodes.
//!
//! The RTF schedules a stage's fine-grain tasks onto a node's CPU cores
//! and accelerators by estimated acceleration (PATS, paper refs
//! [27, 35-39]). With the application's speedup profile (wavefront
//! tasks t2/t6 accelerate ~9-11×, area filters ~1.5×), PATS keeps the
//! scarce accelerator busy on the tasks where it pays.

use rtf_reuse::benchx::{fmt_secs, Table};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::prepare;
use rtf_reuse::merging::FineAlgorithm;
use rtf_reuse::simulate::{
    default_cost_model, hetero_unit_makespan, DeviceModel, SchedulePolicy,
};

fn main() {
    let cfg = StudyConfig {
        method: SaMethod::Moat { r: 20 },
        algorithm: FineAlgorithm::Rtma(7),
        ..StudyConfig::default()
    };
    let p = prepare(&cfg);
    let plan = p.plan(&cfg);
    let model = default_cost_model();

    let mut t = Table::new(&[
        "node (cpu+acc)", "FCFS Σunits", "PATS Σunits", "PATS gain %", "vs cpu-only",
    ]);
    let merged: Vec<_> = plan.units.iter().filter(|u| u.nodes.len() >= 2).collect();
    let cpu_only = DeviceModel::new(4, 0);
    let base: f64 = merged
        .iter()
        .map(|u| {
            hetero_unit_makespan(u, &p.graph, &p.instances, &model, &cpu_only, SchedulePolicy::Pats)
        })
        .sum();

    for (cpus, accs) in [(4usize, 1usize), (4, 2), (8, 2), (16, 4)] {
        let devices = DeviceModel::paper_profile(cpus, accs);
        let total = |policy| -> f64 {
            merged
                .iter()
                .map(|u| hetero_unit_makespan(u, &p.graph, &p.instances, &model, &devices, policy))
                .sum()
        };
        let fcfs = total(SchedulePolicy::Fcfs);
        let pats = total(SchedulePolicy::Pats);
        t.row(&[
            format!("{cpus}+{accs}"),
            fmt_secs(fcfs),
            fmt_secs(pats),
            format!("{:+.1}", (1.0 - pats / fcfs) * 100.0),
            format!("{:.2}x", base / pats),
        ]);
    }
    t.print(&format!(
        "ablation — PATS vs FCFS over {} merged units (MOAT sample {})",
        merged.len(),
        20 * 16
    ));
    println!("(cpu-only baseline: {} across the same units)", fmt_secs(base));
}
