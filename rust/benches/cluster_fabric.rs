//! Two-node cluster-fabric benchmark: what the rendezvous-partitioned
//! remote tier saves the second node of a cluster.
//!
//! Phase 1 runs the study on a plain single node — the cold-cache
//! baseline, i.e. what node B would pay with no fabric. Phase 2 boots a
//! two-node loopback cluster, runs the same study cold on node A (whose
//! write-through publishes B-owned entries over `cache-put`), then on
//! node B (whose misses come back over `cache-get`). Acceptance: node
//! B's launches are strictly fewer than the cold baseline, its bill
//! shows remote hits, and on both nodes the per-tenant scoped counters
//! sum exactly to the globals. Counts, so asserted in `--test` (CI
//! smoke) mode too. Writes `BENCH_cluster.json`.

use std::net::TcpListener;
use std::thread;
use std::time::Instant;

use rtf_reuse::benchx::fmt_secs;
use rtf_reuse::cache::CacheConfig;
use rtf_reuse::serve::{run_jobs, JobSpec, ServeOptions, ServiceReport, StudyService, WireServer};

fn reserve_addr() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("reserve a port");
    listener.local_addr().expect("reserved addr").to_string()
}

fn opts(peers: &[String], own: Option<&str>) -> ServeOptions {
    ServeOptions {
        service_workers: 1,
        study_workers: 2,
        cache: CacheConfig { capacity_bytes: 512 * 1024 * 1024, ..CacheConfig::default() },
        peers: peers.to_vec(),
        cluster_addr: own.map(str::to_string),
        ..ServeOptions::default()
    }
}

fn spawn_node(opts: ServeOptions, addr: &str) -> thread::JoinHandle<ServiceReport> {
    let svc = StudyService::start(opts).expect("node starts");
    let server = WireServer::bind(svc, addr).expect("node binds");
    thread::spawn(move || server.run().expect("node drains cleanly"))
}

fn assert_scoped_sums(report: &ServiceReport, node: &str) {
    let sums = report.scoped_totals();
    assert_eq!(sums.hits, report.cache.hits, "{node}: scoped hits");
    assert_eq!(sums.disk_hits, report.cache.disk_hits, "{node}: scoped disk hits");
    assert_eq!(sums.remote_hits, report.cache.remote_hits, "{node}: scoped remote hits");
    assert_eq!(sums.misses, report.cache.misses, "{node}: scoped misses");
    assert_eq!(sums.inserts, report.cache.inserts, "{node}: scoped inserts");
}

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    let args: Vec<String> =
        vec!["method=moat".into(), format!("r={}", if test_mode { 1 } else { 2 })];
    let spec = |tenant: &str| JobSpec { tenant: tenant.into(), args: args.clone(), tune: false };

    // phase 1: cold-cache baseline — one plain node, no fabric
    let solo_addr = reserve_addr();
    let solo = spawn_node(opts(&[], None), &solo_addr);
    let t0 = Instant::now();
    run_jobs(&solo_addr, &[spec("solo")], true).expect("solo run");
    let solo_wall = t0.elapsed().as_secs_f64();
    let solo_report = solo.join().expect("solo joins");
    assert!(solo_report.jobs[0].ok(), "solo job failed: {:?}", solo_report.jobs[0].error);
    let baseline_launches = solo_report.jobs[0].launches;

    // phase 2: a two-node cluster over loopback
    let addr_a = reserve_addr();
    let addr_b = reserve_addr();
    let peers = vec![addr_a.clone(), addr_b.clone()];
    let node_a = spawn_node(opts(&peers, Some(&addr_a)), &addr_a);
    let node_b = spawn_node(opts(&peers, Some(&addr_b)), &addr_b);

    let t0 = Instant::now();
    run_jobs(&addr_a, &[spec("cold")], false).expect("run on node A");
    let wall_a = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    run_jobs(&addr_b, &[spec("warm")], false).expect("run on node B");
    let wall_b = t0.elapsed().as_secs_f64();

    // drain B first (its shard of A-owned keys needs A alive), then A
    run_jobs(&addr_b, &[], true).expect("drain B");
    run_jobs(&addr_a, &[], true).expect("drain A");
    let report_a = node_a.join().expect("node A joins");
    let report_b = node_b.join().expect("node B joins");
    assert!(report_a.jobs[0].ok(), "node A job failed: {:?}", report_a.jobs[0].error);
    assert!(report_b.jobs[0].ok(), "node B job failed: {:?}", report_b.jobs[0].error);

    let launches_a = report_a.jobs[0].launches;
    let launches_b = report_b.jobs[0].launches;
    let remote_hits_b = report_b.cache.remote_hits;
    assert_eq!(solo_report.jobs[0].y, report_a.jobs[0].y, "node A matches the baseline");
    assert_eq!(solo_report.jobs[0].y, report_b.jobs[0].y, "node B matches the baseline");
    assert_scoped_sums(&report_a, "node A");
    assert_scoped_sums(&report_b, "node B");

    println!(
        "baseline: {baseline_launches} launches in {} | node A (cold): {launches_a} in {} | \
         node B (fabric): {launches_b} in {}, {remote_hits_b} remote hits",
        fmt_secs(solo_wall),
        fmt_secs(wall_a),
        fmt_secs(wall_b),
    );

    let json = format!(
        "{{\n  \"bench\": \"cluster_fabric\",\n  \"mode\": \"{}\",\n  \
         \"evals\": {},\n  \"baseline_launches\": {baseline_launches},\n  \
         \"node_a_launches\": {launches_a},\n  \"node_b_launches\": {launches_b},\n  \
         \"node_b_remote_hits\": {remote_hits_b},\n  \"baseline_wall_secs\": {solo_wall:.6},\n  \
         \"node_a_wall_secs\": {wall_a:.6},\n  \"node_b_wall_secs\": {wall_b:.6}\n}}\n",
        if test_mode { "test" } else { "full" },
        report_b.jobs[0].n_evals,
    );
    std::fs::write("BENCH_cluster.json", &json).expect("write BENCH_cluster.json");
    println!("wrote BENCH_cluster.json");

    println!(
        "ACCEPTANCE: node B paid {launches_b} launches vs its cold baseline \
         {baseline_launches}, riding {remote_hits_b} remote hits — {}",
        if launches_b < baseline_launches && remote_hits_b > 0 { "PASS" } else { "FAIL" }
    );
    assert!(remote_hits_b > 0, "node B must be served over the fabric");
    assert!(
        launches_b < baseline_launches,
        "node B must launch strictly less than its cold baseline: \
         {launches_b} >= {baseline_launches}"
    );
}
