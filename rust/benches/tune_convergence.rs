//! Tuning acceptance bench: a genetic search over (G1, G2), started
//! deliberately away from the incumbent defaults, must (a) strictly
//! improve on the best initial-population candidate and (b) pay fewer
//! kernel launches when re-run against the warm shared cache — the
//! "optimizers revisit quantized points" reuse claim, count-asserted.
//!
//! Both acceptance metrics are *counts/scores*, not wall times, so they
//! are asserted in `--test` (CI smoke) mode too. Writes
//! `BENCH_tune.json` as the perf-trajectory artifact.

use rtf_reuse::benchx::{fmt_secs, time_once, Table};
use rtf_reuse::config::{CacheSettings, StudyConfig};
use rtf_reuse::driver::{build_cache, make_inputs, prepare_candidates};
use rtf_reuse::sampling::default_space;
use rtf_reuse::tune::{run_tune, ObjectiveKind, TuneOptions, TunerKind};

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    let cfg = StudyConfig {
        cache: CacheSettings { enabled: true, capacity_mb: 512, ..CacheSettings::default() },
        workers: 2,
        ..StudyConfig::default()
    };
    let opts = TuneOptions {
        method: TunerKind::Genetic,
        budget: if test_mode { 32 } else { 64 },
        population: 8,
        active: vec![5, 6], // G1, G2: monotone mask response, steep near the top
        objective: ObjectiveKind::Dice,
        // start in the top third of each grid — away from the mid-grid
        // defaults the reference masks were built with, the way an
        // operator tunes *from* a known-bad incumbent
        init_window: (0.7, 1.0),
        mutation: 0.35,
        ..TuneOptions::default()
    };

    let cache = build_cache(&cfg).expect("cache enabled");
    let probe = prepare_candidates(&cfg, &[default_space().defaults()]);
    let inputs = make_inputs(&cfg, &probe).expect("inputs build");

    let (cold, cold_secs) = time_once(|| {
        run_tune(&cfg, &opts, Some(cache.clone()), None, &inputs).expect("cold tuning run")
    });
    // the same run again: a fresh tuner + memo, but a warm shared cache
    let (warm, warm_secs) = time_once(|| {
        run_tune(&cfg, &opts, Some(cache.clone()), None, &inputs).expect("warm tuning run")
    });

    let mut t = Table::new(&["run", "gens", "evaluated", "memo hits", "launches", "best"]);
    for (name, o) in [("cold", &cold), ("warm", &warm)] {
        t.row(&[
            name.to_string(),
            o.history.len().to_string(),
            o.evaluated.to_string(),
            o.memo_hits.to_string(),
            o.launches.to_string(),
            format!("{:.6}", o.best_score),
        ]);
    }
    t.print("tune convergence (genetic over G1, G2; dice vs. reference)");
    println!(
        "cold: initial best {:.6} -> tuned {:.6} in {}  |  warm rerun: {} launches in {}",
        cold.initial_best_score,
        cold.best_score,
        fmt_secs(cold_secs.as_secs_f64()),
        warm.launches,
        fmt_secs(warm_secs.as_secs_f64())
    );

    let json = format!(
        "{{\n  \"bench\": \"tune_convergence\",\n  \"mode\": \"{}\",\n  \
         \"budget\": {},\n  \"generations\": {},\n  \"evaluated\": {},\n  \
         \"memo_hits\": {},\n  \"initial_best\": {:.6},\n  \"tuned_best\": {:.6},\n  \
         \"cold_launches\": {},\n  \"warm_launches\": {},\n  \
         \"cold_wall_secs\": {:.6},\n  \"warm_wall_secs\": {:.6}\n}}\n",
        if test_mode { "test" } else { "full" },
        opts.budget,
        cold.history.len(),
        cold.evaluated,
        cold.memo_hits,
        cold.initial_best_score,
        cold.best_score,
        cold.launches,
        warm.launches,
        cold_secs.as_secs_f64(),
        warm_secs.as_secs_f64(),
    );
    std::fs::write("BENCH_tune.json", &json).expect("write BENCH_tune.json");
    println!("wrote BENCH_tune.json");

    let improved = cold.best_score > cold.initial_best_score;
    let reused = warm.launches < cold.launches;
    println!(
        "ACCEPTANCE: tuned {:.6} vs initial {:.6}; warm {} vs cold {} launches — {}",
        cold.best_score,
        cold.initial_best_score,
        warm.launches,
        cold.launches,
        if improved && reused { "PASS" } else { "FAIL" }
    );
    assert!(cold.launches > 0, "the cold run must execute kernels");
    assert!(
        improved,
        "tuning must strictly improve on the best initial candidate: {:.6} <= {:.6}",
        cold.best_score, cold.initial_best_score
    );
    assert!(
        reused,
        "a warm tuner must ride the shared cache: {} >= {} launches",
        warm.launches, cold.launches
    );
    // same seed, same search: the warm run reproduces the cold result
    assert_eq!(warm.best_params, cold.best_params, "warm rerun must be bit-identical");
    assert_eq!(warm.best_score.to_bits(), cold.best_score.to_bits());
}
