//! Warm-start micro-benchmark: how much of a restarted service's first
//! job the persisted disk tier pays for.
//!
//! Phase 1 runs one cold study on a service with a disk tier and drains
//! it (populating the tier). Phase 2 measures `ReuseCache::warm_start`
//! itself (scan + pre-admission wall time), then boots a fresh service
//! with warm start on and runs the same study: its launch count and hit
//! counters are the acceptance metrics. Because both metrics are
//! *counts*, they are asserted in `--test` (CI smoke) mode too. Writes
//! `BENCH_serve_warm.json` as the perf-trajectory artifact.

use std::time::Instant;

use rtf_reuse::benchx::fmt_secs;
use rtf_reuse::cache::{CacheConfig, ReuseCache};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::merging::FineAlgorithm;
use rtf_reuse::serve::{ServeOptions, StudyJob, StudyService};

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    let cfg = StudyConfig {
        method: SaMethod::Moat { r: if test_mode { 1 } else { 2 } },
        algorithm: FineAlgorithm::Rtma(7),
        ..StudyConfig::default()
    };
    let dir = std::env::temp_dir().join(format!("rtf-serve-warm-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let disk_cache = CacheConfig {
        capacity_bytes: 512 * 1024 * 1024,
        spill_dir: Some(dir.clone()),
        ..CacheConfig::default()
    };
    let opts = |warm_start: bool| ServeOptions {
        service_workers: 1,
        study_workers: 2,
        cache: disk_cache.clone(),
        warm_start,
        ..ServeOptions::default()
    };

    // phase 1: a cold service populates the disk tier
    let day1 = StudyService::start(opts(false)).expect("cold service starts");
    day1.submit(StudyJob { tenant: "day1".into(), cfg: cfg.clone() }).expect("submit");
    let cold = day1.drain();
    assert!(cold.jobs[0].ok(), "cold job failed: {:?}", cold.jobs[0].error);
    let cold_launches = cold.jobs[0].launches;
    assert!(cold.cache.spilled > 0, "disk tier must be populated");

    // phase 2a: the warm-start pass itself, measured in isolation
    let probe = ReuseCache::new(disk_cache.clone());
    let t0 = Instant::now();
    let scan = probe.warm_start();
    let scan_secs = t0.elapsed().as_secs_f64();
    assert!(scan.admitted > 0, "warm start must admit persisted entries");
    drop(probe);

    // phase 2b: a restarted service with warm start on — the first job
    // of the day is served memory hits
    let day2 = StudyService::start(opts(true)).expect("warm service starts");
    let warm_report = day2.warm_start_report();
    day2.submit(StudyJob { tenant: "day2".into(), cfg }).expect("submit");
    let warm = day2.drain();
    assert!(warm.jobs[0].ok(), "warm job failed: {:?}", warm.jobs[0].error);
    let warm_launches = warm.jobs[0].launches;
    let warm_hits = warm.cache.hits;

    println!(
        "cold: {cold_launches} launches | warm-start: {} of {} entries ({} KiB) in {} | \
         warm job: {warm_launches} launches, {warm_hits} memory hits",
        warm_report.admitted,
        warm_report.scanned,
        warm_report.admitted_bytes / 1024,
        fmt_secs(scan_secs)
    );

    let json = format!(
        "{{\n  \"bench\": \"serve_warm\",\n  \"mode\": \"{}\",\n  \
         \"evals\": {},\n  \"scanned\": {},\n  \"admitted\": {},\n  \
         \"admitted_kib\": {},\n  \"warm_start_secs\": {scan_secs:.6},\n  \
         \"cold_launches\": {cold_launches},\n  \"warm_launches\": {warm_launches},\n  \
         \"warm_memory_hits\": {warm_hits},\n  \"cold_wall_secs\": {:.6},\n  \
         \"warm_wall_secs\": {:.6}\n}}\n",
        if test_mode { "test" } else { "full" },
        warm.jobs[0].n_evals,
        warm_report.scanned,
        warm_report.admitted,
        warm_report.admitted_bytes / 1024,
        cold.jobs[0].exec_wall.as_secs_f64(),
        warm.jobs[0].exec_wall.as_secs_f64(),
    );
    std::fs::write("BENCH_serve_warm.json", &json).expect("write BENCH_serve_warm.json");
    println!("wrote BENCH_serve_warm.json");

    println!(
        "ACCEPTANCE: restarted service's first job paid {warm_launches} launches vs cold \
         {cold_launches}, with {warm_hits} memory hits — {}",
        if warm_hits > 0 && warm_launches < cold_launches { "PASS" } else { "FAIL" }
    );
    assert!(warm_hits > 0, "the first job after a warm start must find memory hits");
    assert!(
        warm_launches < cold_launches,
        "warm-started job must reuse persisted work: {warm_launches} >= {cold_launches}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
