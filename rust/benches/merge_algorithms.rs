//! Merge-algorithm microbenchmark: reuse-analysis cost of every
//! fine-grain algorithm as the stage count grows.
//!
//! This is the scalability argument of paper §3.3: Naïve and RTMA scale
//! ~linearly (hash-trie), TRTMA ~O(n²) worst-case, SCA O(n⁴) — the
//! reason SCA DNFs at VBD sample sizes (Fig. 20).

use std::time::Duration;

use rtf_reuse::benchx::{fmt_secs, time_once, Table};
use rtf_reuse::data::SplitMix64;
use rtf_reuse::merging::reuse_tree::ReuseTree;
use rtf_reuse::merging::{
    naive_merge, reuse_fraction, rtma_merge, sca_merge, trtma_merge, MergeStage, TrtmaOptions,
};

/// MOAT-shaped stage population: families sharing long prefixes.
fn population(n: usize, seed: u64) -> Vec<MergeStage> {
    let mut rng = SplitMix64::new(seed);
    (0..n)
        .map(|i| {
            let fam = rng.uniform_usize(0, (n / 8).max(2)) as u64;
            let sub = rng.uniform_usize(0, 4) as u64;
            let path = vec![
                fam,
                fam * 31 + sub,
                fam * 31 + sub * 7 + rng.next_u64() % 3,
                rng.next_u64() % 97,
                rng.next_u64() % 997,
                rng.next_u64() % 9973,
                rng.next_u64(),
            ];
            MergeStage::new(i, path)
        })
        .collect()
}

fn main() {
    let sca_cap = 700; // SCA beyond this would dominate the bench (paper: DNF)
    let mut t = Table::new(&["n", "tree build", "naive", "rtma", "trtma", "sca"]);
    let mut q = Table::new(&["n", "naive reuse %", "rtma reuse %", "trtma reuse %", "sca reuse %"]);

    for n in [100usize, 200, 400, 800, 1600, 3200] {
        let stages = population(n, 42);
        let (_, d_tree) = time_once(|| ReuseTree::build(&stages));
        let (b_naive, d_naive) = time_once(|| naive_merge(&stages, 7));
        let (b_rtma, d_rtma) = time_once(|| rtma_merge(&stages, 7));
        let (b_trtma, d_trtma) =
            time_once(|| trtma_merge(&stages, TrtmaOptions::new((n / 7).max(1))));
        let (b_sca, d_sca) = if n <= sca_cap {
            let (b, d) = time_once(|| sca_merge(&stages, 7));
            (Some(b), Some(d))
        } else {
            (None, None)
        };

        t.row(&[
            n.to_string(),
            fmt_secs(d_tree.as_secs_f64()),
            fmt_secs(d_naive.as_secs_f64()),
            fmt_secs(d_rtma.as_secs_f64()),
            fmt_secs(d_trtma.as_secs_f64()),
            d_sca.map(|d: Duration| fmt_secs(d.as_secs_f64())).unwrap_or("DNF".into()),
        ]);
        q.row(&[
            n.to_string(),
            format!("{:.1}", reuse_fraction(&stages, &b_naive) * 100.0),
            format!("{:.1}", reuse_fraction(&stages, &b_rtma) * 100.0),
            format!("{:.1}", reuse_fraction(&stages, &b_trtma) * 100.0),
            b_sca
                .map(|b| format!("{:.1}", reuse_fraction(&stages, &b) * 100.0))
                .unwrap_or("-".into()),
        ]);
    }

    t.print("merge-analysis cost vs stage count (paper §3.3 complexity claims)");
    q.print("reuse quality per algorithm (SCA ≈ RTMA; naive order-sensitive)");
}
