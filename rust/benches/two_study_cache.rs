//! Acceptance benchmark for the cross-study reuse cache: the same MOAT
//! study executed twice — first cache-cold, then cache-warm — must show a
//! ≥ 1.5× wall-clock speedup on the second execution (the recurrent-SA
//! scenario of arXiv:1910.14548: tuning loops and refinement passes
//! re-run largely overlapping task chains).
//!
//! Also reports a partial-overlap variant (second study widens the
//! design) and verifies that cached execution is bit-identical to cold
//! execution.

use std::sync::Arc;

use rtf_reuse::benchx::{fmt_secs, time_once, Table};
use rtf_reuse::cache::{CacheConfig, ReuseCache};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{make_inputs, prepare, prune_plan_with_inputs, run_pjrt_with_inputs};
use rtf_reuse::merging::FineAlgorithm;

fn main() {
    // `--test`: a smaller design for CI smoke; the speedup is reported
    // but not asserted (shared runners are noisy).
    let test_mode = std::env::args().any(|a| a == "--test");
    let cfg = StudyConfig {
        method: SaMethod::Moat { r: if test_mode { 1 } else { 2 } },
        algorithm: FineAlgorithm::Rtma(7),
        workers: 2,
        ..StudyConfig::default()
    };
    let cache = Arc::new(ReuseCache::new(CacheConfig {
        capacity_bytes: 512 * 1024 * 1024,
        ..CacheConfig::default()
    }));

    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);
    // tiles + reference masks, built once and shared by every phase
    let inputs = make_inputs(&cfg, &prepared).expect("study inputs");

    // baseline: no cache at all
    let (base, d_none) =
        time_once(|| run_pjrt_with_inputs(&cfg, &prepared, &plan, None, &inputs));
    let base = base.expect("baseline study");

    // study 1: cache-cold (pays the insert overhead)
    let (cold, d_cold) = time_once(|| {
        run_pjrt_with_inputs(&cfg, &prepared, &plan, Some(cache.clone()), &inputs)
    });
    let cold = cold.expect("cold study");

    // study 2: identical design, cache-warm
    let prepared2 = prepare(&cfg);
    let mut plan2 = prepared2.plan(&cfg);
    let predicted = prune_plan_with_inputs(&prepared2, &mut plan2, &cache, &inputs);
    let (warm, d_warm) = time_once(|| {
        run_pjrt_with_inputs(&cfg, &prepared2, &plan2, Some(cache.clone()), &inputs)
    });
    let warm = warm.expect("warm study");

    // reuse must never change results
    for (i, (a, b)) in base.y.iter().zip(&warm.y).enumerate() {
        assert!(
            (a - b).abs() < 1e-6,
            "eval {i}: cached result drifted ({a} vs {b})"
        );
    }

    let speedup = d_cold.as_secs_f64() / d_warm.as_secs_f64();
    let mut t = Table::new(&["phase", "wall", "vs cold", "state hits", "metric hits"]);
    let s1 = cold.cache.expect("stats");
    let s2 = warm.cache.expect("stats");
    t.row(&[
        "no cache".into(),
        fmt_secs(d_none.as_secs_f64()),
        format!("{:.2}x", d_cold.as_secs_f64() / d_none.as_secs_f64()),
        "-".into(),
        "-".into(),
    ]);
    t.row(&[
        "study 1 (cold)".into(),
        fmt_secs(d_cold.as_secs_f64()),
        "1.00x".into(),
        (s1.hits + s1.disk_hits).to_string(),
        s1.metric_hits.to_string(),
    ]);
    t.row(&[
        "study 2 (warm)".into(),
        fmt_secs(d_warm.as_secs_f64()),
        format!("{speedup:.2}x"),
        (s2.hits + s2.disk_hits - s1.hits - s1.disk_hits).to_string(),
        (s2.metric_hits - s1.metric_hits).to_string(),
    ]);
    t.print("two-study cross-study reuse (same design, warm second run)");
    println!(
        "planning predicted {predicted} cached tasks; plan2 residual cost {}",
        plan2.tasks_to_execute()
    );
    println!(
        "ACCEPTANCE: warm-study speedup {speedup:.2}x (required >= 1.5x) — {}",
        if speedup >= 1.5 { "PASS" } else { "FAIL" }
    );
    if !test_mode {
        assert!(
            speedup >= 1.5,
            "cross-study cache must give >= 1.5x on the warm study, got {speedup:.2}x"
        );
    }
}
