//! Acceptance benchmark for fine-grain frontier batching: a
//! fan-out-heavy Morris study executed on ONE worker, node-at-a-time
//! (`batch-width=1`, the old DFS cost profile) vs. frontier-batched
//! (`batch-width=16`, one kernel launch per reuse-tree level chunk).
//! Batched execution must be ≥ 1.5× faster with bit-identical
//! per-evaluation metrics.
//!
//! Also reports the planner's launch model (launches at width 1 vs 16)
//! and a cache-warm batched phase whose hits are refcount bumps on the
//! shared cache states (zero-copy hit path).
//!
//! `--test` runs a smaller design for CI smoke (no hard assertion —
//! shared runners are noisy) and still writes the `BENCH_frontier.json`
//! perf-trajectory artifact.

use std::sync::Arc;

use rtf_reuse::benchx::{fmt_secs, time_once, Table};
use rtf_reuse::cache::ReuseCache;
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{make_inputs, prepare, run_pjrt_with_inputs};
use rtf_reuse::merging::{unit_launch_count, FineAlgorithm, TrtmaOptions};

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    let r = if test_mode { 1 } else { 2 };
    let mut cfg = StudyConfig {
        method: SaMethod::Moat { r },
        // one bucket per merge group: maximal fan-out under shared prefixes
        algorithm: FineAlgorithm::Trtma(TrtmaOptions::new(1)),
        workers: 1,
        batch_width: 1,
        ..StudyConfig::default()
    };
    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);
    let inputs = make_inputs(&cfg, &prepared).expect("study inputs");

    let launches = |w: usize| -> usize {
        plan.units
            .iter()
            .map(|u| unit_launch_count(u, &prepared.graph, &prepared.instances, w))
            .sum()
    };
    let (launches_seq, launches_bat) = (launches(1), launches(16));

    // phase 1: node-at-a-time baseline (one backend call per tree node)
    let (seq, d_seq) = time_once(|| run_pjrt_with_inputs(&cfg, &prepared, &plan, None, &inputs));
    let seq = seq.expect("sequential study");

    // phase 2: frontier-batched
    cfg.batch_width = 16;
    let (bat, d_bat) = time_once(|| run_pjrt_with_inputs(&cfg, &prepared, &plan, None, &inputs));
    let bat = bat.expect("batched study");

    // batching must never change results
    for (i, (a, b)) in seq.metrics.iter().zip(&bat.metrics).enumerate() {
        assert_eq!(a, b, "eval {i}: batched metrics drifted from node-at-a-time");
    }

    // phase 3: batched + warm cache — hits are Arc refcount bumps
    let cache = Arc::new(ReuseCache::with_capacity(512 * 1024 * 1024));
    let cold =
        run_pjrt_with_inputs(&cfg, &prepared, &plan, Some(cache.clone()), &inputs).expect("cold");
    let cold_stats = cold.cache.expect("stats");
    let (warm, d_warm) = time_once(|| {
        run_pjrt_with_inputs(&cfg, &prepared, &plan, Some(cache.clone()), &inputs)
    });
    let warm = warm.expect("warm study");
    let warm_stats = warm.cache.expect("stats");
    for (a, b) in seq.metrics.iter().zip(&warm.metrics) {
        assert_eq!(a, b, "cache-served batched metrics drifted");
    }
    // counters accumulate over the cache lifetime: diff the snapshots
    let warm_hits = warm_stats.hits + warm_stats.disk_hits - cold_stats.hits - cold_stats.disk_hits;
    let warm_misses = warm_stats.misses - cold_stats.misses;
    let hit_rate = if warm_hits + warm_misses == 0 {
        0.0
    } else {
        warm_hits as f64 / (warm_hits + warm_misses) as f64
    };

    let speedup = d_seq.as_secs_f64() / d_bat.as_secs_f64();
    let mut t = Table::new(&["phase", "wall", "vs node-at-a-time", "launches"]);
    t.row(&[
        "node-at-a-time (width 1)".into(),
        fmt_secs(d_seq.as_secs_f64()),
        "1.00x".into(),
        launches_seq.to_string(),
    ]);
    t.row(&[
        "frontier-batched (width 16)".into(),
        fmt_secs(d_bat.as_secs_f64()),
        format!("{speedup:.2}x"),
        launches_bat.to_string(),
    ]);
    t.row(&[
        "batched + warm cache".into(),
        fmt_secs(d_warm.as_secs_f64()),
        format!("{:.2}x", d_seq.as_secs_f64() / d_warm.as_secs_f64()),
        "-".into(),
    ]);
    t.print("frontier batching on a fan-out-heavy Morris study (1 worker)");
    println!("warm-phase state hit rate: {:.1}% ({warm_hits} hits)", hit_rate * 100.0);

    let json = format!(
        "{{\n  \"bench\": \"frontier_batching\",\n  \"mode\": \"{}\",\n  \
         \"evals\": {},\n  \"wall_sequential_secs\": {:.6},\n  \
         \"wall_batched_secs\": {:.6},\n  \"speedup\": {:.4},\n  \
         \"launches_sequential\": {launches_seq},\n  \"launches_batched\": {launches_bat},\n  \
         \"warm_wall_secs\": {:.6},\n  \"warm_cache_hit_rate\": {:.4}\n}}\n",
        if test_mode { "test" } else { "full" },
        prepared.n_evals(),
        d_seq.as_secs_f64(),
        d_bat.as_secs_f64(),
        speedup,
        d_warm.as_secs_f64(),
        hit_rate,
    );
    std::fs::write("BENCH_frontier.json", &json).expect("write BENCH_frontier.json");
    println!("wrote BENCH_frontier.json");

    println!(
        "ACCEPTANCE: batched speedup {speedup:.2}x (required >= 1.5x, single worker) — {}",
        if speedup >= 1.5 { "PASS" } else { "FAIL" }
    );
    if !test_mode {
        assert!(
            speedup >= 1.5,
            "frontier batching must be >= 1.5x over node-at-a-time, got {speedup:.2}x"
        );
    }
}
