//! Paper Fig. 21: impact of MaxBucketSize (2..8) on RTMA execution time.
//!
//! Expected shape: larger buckets → more merging → smaller makespan,
//! with diminishing returns once the design's sharing groups are
//! captured; the end-to-end spread stays modest (paper: ≤ ~12% between
//! MBS 2 and 8), which is what makes fine-grain reuse viable on
//! memory-constrained nodes (small MBS ⇒ bounded merged-stage state).

use rtf_reuse::benchx::{fmt_secs, Table};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{prepare, run_sim};
use rtf_reuse::merging::FineAlgorithm;
use rtf_reuse::simulate::{default_cost_model, SimOptions};

fn main() {
    let model = default_cost_model();
    let workers = 6;
    let r = 30; // sample 480
    let mut t = Table::new(&["MaxBucketSize", "makespan", "reuse %", "seg units", "vs MBS=2"]);

    let mut base = None;
    for mbs in 2usize..=8 {
        let cfg = StudyConfig {
            method: SaMethod::Moat { r },
            algorithm: FineAlgorithm::Rtma(mbs),
            workers,
            ..StudyConfig::default()
        };
        let prepared = prepare(&cfg);
        let plan = prepared.plan(&cfg);
        let opts = SimOptions::new(workers);
        let rep = run_sim(&prepared, &plan, &model, &opts);
        if base.is_none() {
            base = Some(rep.makespan);
        }
        t.row(&[
            mbs.to_string(),
            fmt_secs(rep.makespan),
            format!("{:.1}", plan.fine_reuse() * 100.0),
            plan.units_of_stage(1).len().to_string(),
            format!("{:+.1}%", (rep.makespan / base.unwrap() - 1.0) * 100.0),
        ]);
    }
    t.print(&format!(
        "Fig. 21 — MaxBucketSize sweep, MOAT sample {}, {workers} workers",
        r * 16
    ));
}
