//! Ablation: count-balanced TRTMA (paper §3.3.4) vs the cost-balanced
//! TRTMA the paper's conclusion proposes as future work (§5).
//!
//! Under the paper's own Table-6 cost profile (t6 = 39.6% of a stage),
//! two buckets with equal task *counts* can differ ~1.26× in cost
//! (Fig. 24). Balancing by estimated cost removes that residual
//! imbalance; the effect concentrates at low buckets-per-worker ratios
//! where one hot bucket sets the makespan. Also ablated: the smallRT
//! best-reuse selection strategy (paper: negligible — reproduced).

use rtf_reuse::benchx::{fmt_secs, Table};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{prepare, run_sim};
use rtf_reuse::merging::{FineAlgorithm, TrtmaOptions};
use rtf_reuse::simulate::{default_cost_model, SimOptions};

fn main() {
    let model = default_cost_model();
    let r = 31; // sample 496
    let mut t = Table::new(&[
        "WP", "TRTMA (count)", "TRTMA (cost)", "gain %", "util count %", "util cost %",
    ]);

    for wp in [16usize, 32, 64, 128] {
        let mk = |algo: FineAlgorithm| {
            let cfg = StudyConfig {
                method: SaMethod::Moat { r },
                algorithm: algo,
                workers: wp,
                ..StudyConfig::default()
            };
            let prepared = prepare(&cfg);
            let plan = prepared.plan(&cfg);
            let opts = SimOptions::new(wp).with_cv(0.0, 42);
            run_sim(&prepared, &plan, &model, &opts)
        };
        let count = mk(FineAlgorithm::Trtma(TrtmaOptions::new(3 * wp)));
        let cost = mk(FineAlgorithm::TrtmaCost(TrtmaOptions::new(3 * wp)));
        t.row(&[
            wp.to_string(),
            fmt_secs(count.makespan),
            fmt_secs(cost.makespan),
            format!("{:+.1}", (1.0 - cost.makespan / count.makespan) * 100.0),
            format!("{:.1}", count.utilization() * 100.0),
            format!("{:.1}", cost.utilization() * 100.0),
        ]);
    }
    t.print(&format!(
        "ablation — count- vs cost-balanced TRTMA, MOAT sample {}, Table-6 costs",
        r * 16
    ));

    // smallRT selection strategy ablation (paper §3.3.4 Discussion)
    let mut t2 = Table::new(&["strategy", "makespan", "reuse %"]);
    for (name, best_reuse) in [("last bucket (default)", false), ("best-reuse smallRT", true)] {
        let mut opts = TrtmaOptions::new(48);
        opts.smallrt_best_reuse = best_reuse;
        let cfg = StudyConfig {
            method: SaMethod::Moat { r },
            algorithm: FineAlgorithm::Trtma(opts),
            workers: 16,
            ..StudyConfig::default()
        };
        let prepared = prepare(&cfg);
        let plan = prepared.plan(&cfg);
        let rep = run_sim(&prepared, &plan, &model, &SimOptions::new(16));
        t2.row(&[
            name.to_string(),
            fmt_secs(rep.makespan),
            format!("{:.2}", plan.fine_reuse() * 100.0),
        ]);
    }
    t2.print("ablation — smallRT selection (paper: negligible difference)");
}
