//! Paper Table 6: empirical per-task cost breakdown of the segmentation
//! stage, measured on the real PJRT execution of the AOT artifacts.
//!
//! Absolute seconds differ from the paper's Stampede/OpenCV numbers (we
//! run 128×128 synthetic tiles through XLA CPU); the quantity that must
//! hold is the *shape*: task costs are far from uniform, with the
//! irregular-wavefront tasks (t2 morphological reconstruction, t6
//! watershed) dominating — the reason task-count-balanced buckets can
//! still be imbalanced (paper §4.5.1, Fig. 24).

use rtf_reuse::benchx::{fmt_secs, Table};
use rtf_reuse::config::StudyConfig;
use rtf_reuse::driver::{make_tiles, reference_masks};
use rtf_reuse::runtime::PjrtEngine;
use rtf_reuse::sampling::default_space;
use rtf_reuse::simulate::default_cost_model;
use rtf_reuse::workflow::paper_workflow;

fn main() {
    let cfg = StudyConfig { tiles: 4, ..StudyConfig::default() };
    let mut engine = PjrtEngine::load(&cfg.artifacts_dir).expect("run `make artifacts` first");
    let (h, w) = engine.tile_shape();
    let space = default_space();
    let wf = paper_workflow();
    let tiles = make_tiles(&cfg, h, w);

    // repeated chain executions over several tiles for stable means
    for _ in 0..5 {
        let _ = reference_masks(&mut engine, &space, &wf, &tiles).unwrap();
    }

    let rows = engine.timer().summary();
    let seg: f64 = rows
        .iter()
        .filter(|(n, _, _)| n.starts_with('t'))
        .map(|(_, m, _)| m)
        .sum();
    let paper = default_cost_model();
    let paper_seg: f64 = (1..=7).map(|i| paper.cost_of(&format!("t{i}"))).sum();

    let mut t = Table::new(&["task", "mean", "share %", "paper share %", "runs"]);
    for (name, mean, n) in &rows {
        if !name.starts_with('t') {
            continue;
        }
        t.row(&[
            name.clone(),
            fmt_secs(*mean),
            format!("{:.2}", mean / seg * 100.0),
            format!("{:.2}", paper.cost_of(name) / paper_seg * 100.0),
            n.to_string(),
        ]);
    }
    t.print("Table 6 — per-task cost breakdown (measured via PJRT vs paper shares)");
    println!("segmentation stage total: {} per tile", fmt_secs(seg));
}
