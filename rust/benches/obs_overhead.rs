//! Acceptance benchmark for the telemetry invariant's first half:
//! **telemetry on must be (nearly) free**. The same frontier-batched
//! Morris study runs twice — once with the `Obs` handle off (the
//! production default) and once tracing every span to a JSONL file
//! with the metrics registry live — and the telemetry-on run must keep
//! ≥ 0.95× the telemetry-off throughput. The second half of the
//! invariant (on never changes a result) is asserted here too: the
//! traced run's metrics must be bit-identical to the untraced run's.
//!
//! Each arm takes the best of several repetitions (one warm-up run
//! first), so the ratio compares steady-state walls, not allocator or
//! page-cache noise. Unlike the throughput benches, the ratio IS
//! asserted in `--test` mode: it is a same-machine, same-binary
//! comparison, so CI noise cancels.
//!
//! Writes the `BENCH_obs.json` perf-trajectory artifact.

use std::sync::Arc;
use std::time::{Duration, Instant};

use rtf_reuse::benchx::{fmt_secs, time_once, Table};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{make_inputs, prepare, run_pjrt_with_inputs};
use rtf_reuse::obs::{span, Obs, SpanCtx};

const MIN_RATIO: f64 = 0.95;

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    let r = if test_mode { 1 } else { 2 };
    let reps = if test_mode { 3 } else { 5 };
    let mut cfg = StudyConfig {
        method: SaMethod::Moat { r },
        workers: 2,
        batch_width: 16,
        ..StudyConfig::default()
    };
    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);
    let inputs = make_inputs(&cfg, &prepared).expect("study inputs");

    // warm-up: first run pays one-time costs (lazy init, page faults)
    let baseline =
        run_pjrt_with_inputs(&cfg, &prepared, &plan, None, &inputs).expect("warm-up study");

    // arm 1: telemetry off — every instrumentation site is one branch
    let mut d_off = Duration::MAX;
    for _ in 0..reps {
        let (out, d) = time_once(|| run_pjrt_with_inputs(&cfg, &prepared, &plan, None, &inputs));
        out.expect("untraced study");
        d_off = d_off.min(d);
    }

    // arm 2: telemetry on — every span to a JSONL sink, registry live
    let trace_path =
        std::env::temp_dir().join(format!("rtf-obs-overhead-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&trace_path);
    cfg.obs = Obs::to_file("bench", &trace_path).expect("trace sink");
    let mut d_on = Duration::MAX;
    let mut traced_metrics = Vec::new();
    for rep in 0..reps {
        // one root job span per repetition, like the service would mint
        let o = cfg.obs.get().expect("active handle").clone();
        let root = o.next_span();
        cfg.trace = Some(SpanCtx {
            trace: o.new_trace(),
            parent: root,
            tenant: Arc::from("bench"),
            job: rep as u64,
        });
        let started = Instant::now();
        let (out, d) = time_once(|| run_pjrt_with_inputs(&cfg, &prepared, &plan, None, &inputs));
        let out = out.expect("traced study");
        let ctx = SpanCtx { parent: 0, ..cfg.trace.clone().expect("ctx") };
        o.emit_timed(&ctx, span::JOB, root, started, d, "obs_overhead rep".into());
        d_on = d_on.min(d);
        traced_metrics = out.metrics;
    }
    if let Some(o) = cfg.obs.get() {
        o.flush();
    }

    // telemetry on never changes a result
    for (i, (a, b)) in baseline.metrics.iter().zip(&traced_metrics).enumerate() {
        assert_eq!(a, b, "eval {i}: traced metrics drifted from untraced");
    }
    // ... and it actually recorded the run: spans in the file, launches
    // in the registry
    let snap = cfg.obs.get().expect("active handle").snapshot();
    let launches = snap.global.counter("launches");
    assert!(launches > 0, "traced run recorded no launches");
    let trace_lines =
        std::fs::read_to_string(&trace_path).expect("trace file").lines().count();
    assert!(trace_lines > 0, "trace sink is empty");
    let _ = std::fs::remove_file(&trace_path);

    let ratio = d_off.as_secs_f64() / d_on.as_secs_f64();
    let mut t = Table::new(&["arm", "wall (best)", "throughput vs off"]);
    t.row(&["telemetry off".into(), fmt_secs(d_off.as_secs_f64()), "1.00x".into()]);
    t.row(&[
        "telemetry on (trace + stats)".into(),
        fmt_secs(d_on.as_secs_f64()),
        format!("{ratio:.3}x"),
    ]);
    t.print("telemetry overhead on a frontier-batched Morris study");
    println!("traced spans: {trace_lines} lines, launches counted: {launches}");

    let json = format!(
        "{{\n  \"bench\": \"obs_overhead\",\n  \"mode\": \"{}\",\n  \
         \"evals\": {},\n  \"reps\": {reps},\n  \
         \"wall_off_secs\": {:.6},\n  \"wall_on_secs\": {:.6},\n  \
         \"throughput_ratio\": {:.4},\n  \"trace_lines\": {trace_lines},\n  \
         \"launches\": {launches}\n}}\n",
        if test_mode { "test" } else { "full" },
        prepared.n_evals(),
        d_off.as_secs_f64(),
        d_on.as_secs_f64(),
        ratio,
    );
    std::fs::write("BENCH_obs.json", &json).expect("write BENCH_obs.json");
    println!("wrote BENCH_obs.json");

    println!(
        "ACCEPTANCE: telemetry-on throughput {ratio:.3}x of telemetry-off \
         (required >= {MIN_RATIO}x) — {}",
        if ratio >= MIN_RATIO { "PASS" } else { "FAIL" }
    );
    assert!(
        ratio >= MIN_RATIO,
        "telemetry must stay >= {MIN_RATIO}x of untraced throughput, got {ratio:.3}x"
    );
}
