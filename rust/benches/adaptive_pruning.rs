//! Adaptive-execution acceptance benchmark: online pruning must pay
//! strictly fewer backend launches than the exhaustive run while every
//! surviving evaluation stays bit-identical, and speculative execution
//! may change timing but never result bytes.
//!
//! Phase 1 runs the exhaustive MOAT study, derives a pruning threshold
//! from its own two-trajectory confidence intervals (the state the
//! online pruner sees at its first decision point), and re-runs the
//! study adaptively at that threshold. Phase 2 runs the same GA tuning
//! job on a speculation-off and a speculation-on service and compares
//! the results byte for byte. Both properties are *count/byte*
//! assertions, so they hold in `--test` (CI smoke) mode too. Writes
//! `BENCH_adaptive.json` as the perf-trajectory artifact.

use std::time::Instant;

use rtf_reuse::adaptive::{run_adaptive, AdaptiveOptions, StreamingMoat};
use rtf_reuse::benchx::fmt_secs;
use rtf_reuse::cache::CacheConfig;
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{
    build_cache, make_inputs, prepare, prune_plan_with_inputs, run_pjrt_with_inputs_scoped,
    y_per_set, SampleInfo,
};
use rtf_reuse::merging::FineAlgorithm;
use rtf_reuse::serve::{ServeOptions, StudyService};
use rtf_reuse::tune::{TuneOptions, TunerKind};

fn main() {
    let test_mode = std::env::args().any(|a| a == "--test");
    let cfg = StudyConfig {
        method: SaMethod::Moat { r: if test_mode { 4 } else { 8 } },
        algorithm: FineAlgorithm::Rtma(7),
        ..StudyConfig::default()
    };

    // phase 1a: the exhaustive run — the ground truth and cost baseline
    let prepared = prepare(&cfg);
    let inputs = make_inputs(&cfg, &prepared).expect("inputs build");
    let cache = build_cache(&cfg);
    let mut plan = prepared.plan(&cfg);
    if let Some(c) = &cache {
        prune_plan_with_inputs(&prepared, &mut plan, c, &inputs);
    }
    let t0 = Instant::now();
    let full = run_pjrt_with_inputs_scoped(&cfg, &prepared, &plan, cache, None, &inputs)
        .expect("exhaustive run completes");
    let full_wall = t0.elapsed().as_secs_f64();
    let full_launches = full.timer.launches();

    // phase 1b: derive the threshold the online pruner will apply —
    // just above the (3k/5)-th smallest μ* CI upper edge after two
    // trajectories, pruning a dense-enough set that later trajectories
    // must drop evaluations
    let SampleInfo::Moat(sample) = &prepared.sample else { panic!("moat study") };
    let k = prepared.space.dim();
    let y_sets = y_per_set(&full.y, sample.sets.len(), cfg.tiles);
    let mut stream = StreamingMoat::new(k);
    let executed = vec![true; sample.sets.len()];
    for t in &sample.trajectories[..2] {
        stream.update(t, &y_sets, &executed);
    }
    let mut uppers: Vec<f64> = (0..k).map(|p| stream.mu_star_upper(p)).collect();
    uppers.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let threshold = uppers[(3 * k) / 5] * (1.0 + 1e-9) + f64::MIN_POSITIVE;

    // phase 1c: the adaptive run at that threshold
    let mut acfg = cfg.clone();
    acfg.adaptive = AdaptiveOptions { enabled: true, threshold, min_samples: 2 };
    let t0 = Instant::now();
    let out = run_adaptive(&acfg).expect("adaptive run completes");
    let adaptive_wall = t0.elapsed().as_secs_f64();

    let mut survivors_identical = true;
    for (g, &alive) in out.survived.iter().enumerate() {
        for t in 0..cfg.tiles {
            let (y, r) = (out.y[g * cfg.tiles + t], full.y[g * cfg.tiles + t]);
            if alive && y.to_bits() != r.to_bits() {
                survivors_identical = false;
            }
            assert!(alive || y == 0.0, "pruned slot {g} must hold the sentinel");
        }
    }
    let survived = out.survived.iter().filter(|s| **s).count();
    println!(
        "exhaustive: {} evals, {full_launches} launches, {} | adaptive(thr={threshold:.4}): \
         {survived} of {} sets executed, {} evals pruned ({} params), {} launches, {}",
        prepared.n_evals(),
        fmt_secs(full_wall),
        out.survived.len(),
        out.pruned,
        out.pruned_params.len(),
        out.launches,
        fmt_secs(adaptive_wall),
    );

    // phase 2: speculation A/B on the serve path — same GA tune job,
    // identical bytes out, speculative launches billed globally
    let serve_run = |speculate: bool| {
        let opts = ServeOptions {
            service_workers: if speculate { 2 } else { 1 },
            study_workers: 2,
            speculate,
            cache: CacheConfig { capacity_bytes: 512 * 1024 * 1024, ..CacheConfig::default() },
            ..ServeOptions::default()
        };
        let tune = TuneOptions {
            method: TunerKind::Genetic,
            budget: if test_mode { 6 } else { 12 },
            population: 3,
            k_active: 2,
            ..TuneOptions::default()
        };
        let svc = StudyService::start(opts).expect("service starts");
        let t0 = Instant::now();
        let id = svc.submit_tune("bench", cfg.clone(), tune).expect("submit tune");
        let report = svc.wait_job(id).expect("job known");
        assert!(report.ok(), "tune job failed: {:?}", report.error);
        while svc.speculative_pending() > 0 {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let wall = t0.elapsed().as_secs_f64();
        let drained = svc.drain();
        (report, drained.speculative_launches, wall)
    };
    let (off, off_spec, off_wall) = serve_run(false);
    let (on, on_spec, on_wall) = serve_run(true);
    assert_eq!(off_spec, 0, "speculation off spends nothing");
    let spec_identical = off.y == on.y && off.tune == on.tune;
    println!(
        "tune speculation off: {} ({} launches) | on: {} ({} launches + {on_spec} speculative) \
         | results identical: {spec_identical}",
        fmt_secs(off_wall),
        off.launches,
        fmt_secs(on_wall),
        on.launches,
    );

    let json = format!(
        "{{\n  \"bench\": \"adaptive_pruning\",\n  \"mode\": \"{}\",\n  \
         \"evals\": {},\n  \"threshold\": {threshold},\n  \
         \"full_launches\": {full_launches},\n  \"adaptive_launches\": {},\n  \
         \"pruned_evals\": {},\n  \"pruned_params\": {},\n  \
         \"survivors_bit_identical\": {survivors_identical},\n  \
         \"full_wall_secs\": {full_wall:.6},\n  \"adaptive_wall_secs\": {adaptive_wall:.6},\n  \
         \"tune_wall_off_secs\": {off_wall:.6},\n  \"tune_wall_on_secs\": {on_wall:.6},\n  \
         \"speculative_launches\": {on_spec},\n  \
         \"speculation_bit_identical\": {spec_identical}\n}}\n",
        if test_mode { "test" } else { "full" },
        prepared.n_evals(),
        out.launches,
        out.pruned,
        out.pruned_params.len(),
    );
    std::fs::write("BENCH_adaptive.json", &json).expect("write BENCH_adaptive.json");
    println!("wrote BENCH_adaptive.json");

    let pass = out.pruned > 0
        && out.launches < full_launches
        && survivors_identical
        && spec_identical;
    println!(
        "ACCEPTANCE: adaptive run paid {} launches vs exhaustive {full_launches} with {} evals \
         pruned, survivors bit-identical: {survivors_identical}; speculation changed result \
         bytes: {} — {}",
        out.launches,
        out.pruned,
        !spec_identical,
        if pass { "PASS" } else { "FAIL" }
    );
    assert!(out.pruned > 0, "the derived threshold must prune");
    assert!(
        out.launches < full_launches,
        "adaptive must pay strictly fewer launches: {} >= {full_launches}",
        out.launches
    );
    assert!(survivors_identical, "surviving evaluations must be bit-identical");
    assert!(spec_identical, "speculation may never change result bytes");
}
