//! Paper Fig. 20: VBD study execution time vs sample size (2000–10000
//! evaluations, 16 workers). The paper's headline here: SCA **does not
//! finish** computing the reuse at VBD scale, while RTMA reaches ~35%
//! reuse with negligible merge time (speedup up to ~2.9× over NR,
//! ~1.5× over stage-level).
//!
//! SCA is extrapolated from its measured small-sample cost instead of
//! executed (O(n⁴): at n=2000 stages a single run would take hours —
//! the same DNF the paper reports at 14 000 s).

use std::time::Instant;

use rtf_reuse::benchx::{fmt_secs, Table};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{prepare, run_sim};
use rtf_reuse::merging::{sca_merge, FineAlgorithm, MergeStage};
use rtf_reuse::simulate::{default_cost_model, SimOptions};

/// Measure SCA on a prefix of the real study's merge population and
/// extrapolate O(n⁴) to the full size.
fn sca_estimate(prepared: &rtf_reuse::driver::PreparedStudy, full_n: usize) -> f64 {
    let probe_n = 300.min(full_n);
    let stages: Vec<MergeStage> = prepared
        .graph
        .nodes
        .iter()
        .filter(|n| n.stage_idx == 1)
        .take(probe_n)
        .enumerate()
        .map(|(i, n)| MergeStage::new(i, prepared.instances[n.rep].task_path()))
        .collect();
    let t0 = Instant::now();
    let _ = sca_merge(&stages, 7);
    let probe = t0.elapsed().as_secs_f64();
    probe * (full_n as f64 / stages.len() as f64).powi(4)
}

fn main() {
    let model = default_cost_model();
    let workers = 16;
    let mut t = Table::new(&[
        "sample", "version", "makespan", "merge", "reuse %", "speedup vs NR",
    ]);

    for n in [200usize, 600, 1000] {
        let sample = n * 10; // k=8 actives: n(k+2)
        let mut nr_total = None;
        for (name, coarse, algo) in [
            ("no reuse", false, FineAlgorithm::None),
            ("stage level", true, FineAlgorithm::None),
            ("naive", true, FineAlgorithm::Naive(7)),
            ("rtma", true, FineAlgorithm::Rtma(7)),
        ] {
            let cfg = StudyConfig {
                method: SaMethod::Vbd { n, k_active: 8 },
                coarse,
                algorithm: algo,
                workers,
                ..StudyConfig::default()
            };
            let prepared = prepare(&cfg);
            let plan = prepared.plan(&cfg);
            let opts = SimOptions::new(workers);
            let rep = run_sim(&prepared, &plan, &model, &opts);
            let total = rep.makespan + plan.merge_time.as_secs_f64();
            if nr_total.is_none() {
                nr_total = Some(total);
                // SCA row: measured probe, extrapolated to full scale
                let est = sca_estimate(&prepared, prepared.graph.nodes_of_stage(1).len());
                t.row(&[
                    sample.to_string(),
                    "sca".to_string(),
                    "DNF".to_string(),
                    format!("~{} (extrapolated)", fmt_secs(est)),
                    "-".to_string(),
                    "-".to_string(),
                ]);
            }
            t.row(&[
                sample.to_string(),
                name.to_string(),
                fmt_secs(rep.makespan),
                fmt_secs(plan.merge_time.as_secs_f64()),
                format!("{:.1}", plan.fine_reuse() * 100.0),
                format!("{:.2}x", nr_total.unwrap() / total),
            ]);
        }
    }
    t.print("Fig. 20 — VBD study, 16 workers (SCA DNF, as in the paper)");
}
