//! Paper Table 5: TRTMA speedup over No-Reuse and TRTMA's attained
//! reuse, WP 8..256 (MOAT sample 1000, MaxBuckets = 3×WP).
//!
//! Expected shape: speedup 1.3× at WP 8 decaying monotonically toward
//! ~1.0× at WP 256, with the attained reuse dropping as the bucket
//! target (3×WP) forces finer partitions (paper: 33% → 10.7%).

use rtf_reuse::benchx::Table;
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{prepare, run_sim};
use rtf_reuse::merging::{FineAlgorithm, TrtmaOptions};
use rtf_reuse::simulate::{default_cost_model, SimOptions};

fn main() {
    let model = default_cost_model();
    let r = 62; // sample 992 ≈ paper's 1000
    let mut t = Table::new(&["WP", "speedup TRTMA vs NR", "TRTMA reuse %"]);

    for wp in [8usize, 16, 32, 64, 128, 256] {
        let mk = |coarse: bool, algo: FineAlgorithm| {
            let cfg = StudyConfig {
                method: SaMethod::Moat { r },
                coarse,
                algorithm: algo,
                workers: wp,
                ..StudyConfig::default()
            };
            let prepared = prepare(&cfg);
            let plan = prepared.plan(&cfg);
            let opts = SimOptions::new(wp).with_cv(0.15, 42);
            (run_sim(&prepared, &plan, &model, &opts), plan)
        };
        let (nr, _) = mk(true, FineAlgorithm::None);
        let (trtma, plan) = mk(true, FineAlgorithm::Trtma(TrtmaOptions::new(3 * wp)));
        t.row(&[
            wp.to_string(),
            format!("{:.2}", nr.makespan / trtma.makespan),
            format!("{:.2}", plan.fine_reuse() * 100.0),
        ]);
    }
    t.print(&format!("Table 5 — TRTMA vs NR, MOAT sample {}", r * 16));
}
