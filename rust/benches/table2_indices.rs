//! Paper Table 2: the two-phase SA outcome — MOAT elementary effects
//! over all 15 parameters, then VBD Sobol indices over the screened
//! top-8 — computed from **real** PJRT executions of the workflow on a
//! synthetic tile.
//!
//! Absolute index values depend on the tile content; the shape that
//! must hold (paper Table 2): the candidate-nuclei thresholds G1/G2
//! dominate, background thresholds B/G/R and the final-output area
//! filters are near-zero, and VBD's main effects agree with the MOAT
//! ranking.

use rtf_reuse::analysis::sobol_indices;
use rtf_reuse::benchx::{fmt_secs, Table};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{moat_screen, prepare, prepare_with_active, run_pjrt, y_per_set, SampleInfo};
use rtf_reuse::merging::FineAlgorithm;

fn main() {
    // ---- MOAT over all 15 parameters --------------------------------
    let cfg = StudyConfig {
        method: SaMethod::Moat { r: 4 }, // 64 evaluations
        algorithm: FineAlgorithm::Rtma(7),
        workers: 4,
        ..StudyConfig::default()
    };
    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);
    let out = run_pjrt(&cfg, &prepared, &plan).expect("run `make artifacts` first");
    let (idx, top) = moat_screen(&cfg, &prepared, &out.y, 8);

    let mut t = Table::new(&["param", "MOAT first-order", "mu*", "sigma"]);
    for p in 0..prepared.space.dim() {
        t.row(&[
            prepared.space.params[p].name.clone(),
            format!("{:+.4}", idx.mean[p]),
            format!("{:.4}", idx.mu_star[p]),
            format!("{:.4}", idx.sigma[p]),
        ]);
    }
    t.print(&format!(
        "Table 2 (left) — MOAT, all 15 params, 64 evals, wall {}",
        fmt_secs(out.wall.as_secs_f64())
    ));

    // ---- VBD over the screened top-8 ---------------------------------
    let vcfg = StudyConfig {
        method: SaMethod::Vbd { n: 8, k_active: top.len() },
        algorithm: FineAlgorithm::Rtma(7),
        workers: 4,
        ..StudyConfig::default()
    };
    let vprep = prepare_with_active(&vcfg, Some(top.clone()));
    let vplan = vprep.plan(&vcfg);
    let vout = run_pjrt(&vcfg, &vprep, &vplan).expect("vbd run");
    let SampleInfo::Vbd(sample, active) = &vprep.sample else { unreachable!() };
    let y = y_per_set(&vout.y, sample.sets.len(), vcfg.tiles);
    let s = sobol_indices(sample, &y);

    let mut t2 = Table::new(&["param", "VBD S_i (main)", "ST_i (total)"]);
    for (i, &p) in active.iter().enumerate() {
        t2.row(&[
            vprep.space.params[p].name.clone(),
            format!("{:.4}", s.first[i]),
            format!("{:.4}", s.total[i]),
        ]);
    }
    t2.print(&format!(
        "Table 2 (right) — VBD over the screened top-{}, {} evals, wall {}",
        active.len(),
        sample.sample_size(),
        fmt_secs(vout.wall.as_secs_f64())
    ));
}
