//! Paper Fig. 22 + Fig. 23: worker scaling of NR vs RTMA vs TRTMA
//! (MOAT sample 1000, WP 8..256) with stages-per-worker ratios and
//! parallel-efficiency series.
//!
//! Expected shape: RTMA (MaxBucketSize 10) wins at low WP but its fixed
//! bucket count starves high WP — it drops below NR; TRTMA
//! (MaxBuckets = 3×WP) adapts its bucket count and stays ≥ NR
//! everywhere, with its advantage fading to ~1.0× at WP 256 (Table 5's
//! companion figure).

use rtf_reuse::benchx::{fmt_secs, Table};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{prepare, run_sim};
use rtf_reuse::merging::{FineAlgorithm, TrtmaOptions};
use rtf_reuse::simulate::{default_cost_model, SimOptions};

fn main() {
    let model = default_cost_model();
    let r = 62; // sample 992 ≈ paper's 1000
    let mut t = Table::new(&["WP", "NR", "RTMA(mbs=10)", "TRTMA(3xWP)", "S/W rtma", "S/W trtma"]);
    let mut eff = Table::new(&["WP", "eff NR", "eff RTMA", "eff TRTMA"]);
    let mut prev: Option<(f64, f64, f64)> = None;

    for wp in [8usize, 16, 32, 64, 128, 256] {
        let mk = |coarse: bool, algo: FineAlgorithm| {
            let cfg = StudyConfig {
                method: SaMethod::Moat { r },
                coarse,
                algorithm: algo,
                workers: wp,
                ..StudyConfig::default()
            };
            let prepared = prepare(&cfg);
            let plan = prepared.plan(&cfg);
            let opts = SimOptions::new(wp).with_cv(0.15, 42);
            (run_sim(&prepared, &plan, &model, &opts), plan)
        };
        let (nr, _) = mk(true, FineAlgorithm::None);
        let (rtma, rtma_plan) = mk(true, FineAlgorithm::Rtma(10));
        let (trtma, trtma_plan) = mk(true, FineAlgorithm::Trtma(TrtmaOptions::new(3 * wp)));

        t.row(&[
            wp.to_string(),
            fmt_secs(nr.makespan),
            fmt_secs(rtma.makespan),
            fmt_secs(trtma.makespan),
            format!("{:.1}", rtma_plan.units_of_stage(1).len() as f64 / wp as f64),
            format!("{:.1}", trtma_plan.units_of_stage(1).len() as f64 / wp as f64),
        ]);
        if let Some((p_nr, p_rt, p_tb)) = prev {
            eff.row(&[
                wp.to_string(),
                format!("{:.2}", p_nr / (nr.makespan * 2.0)),
                format!("{:.2}", p_rt / (rtma.makespan * 2.0)),
                format!("{:.2}", p_tb / (trtma.makespan * 2.0)),
            ]);
        }
        prev = Some((nr.makespan, rtma.makespan, trtma.makespan));
    }
    t.print(&format!("Fig. 22 — scaling, MOAT sample {} (cv=0.15)", r * 16));
    eff.print("Fig. 23 — parallel efficiency vs previous WP (factor 2)");
}
