//! Paper Table 4: maximum fine-grain reuse potential (after coarse
//! reuse) of the MC, LHS and QMC experiment generators over VBD designs
//! with sample size 200/600/1000.
//!
//! Expected shape: all cells 33–37%, stable across sample size, with
//! QMC slightly below MC/LHS (its better space coverage makes rows less
//! likely to coincide).

use rtf_reuse::benchx::{fmt_secs, time_once, Table};
use rtf_reuse::config::{SaMethod, SamplerKind, StudyConfig};
use rtf_reuse::driver::prepare;
use rtf_reuse::merging::{FineAlgorithm, TrtmaOptions};

fn main() {
    let mut t = Table::new(&["sampler", "n=200", "n=600", "n=1000", "analysis time (n=1000)"]);
    for kind in [SamplerKind::Mc, SamplerKind::Lhs, SamplerKind::Qmc] {
        let mut cells = vec![kind.name().to_string()];
        let mut last_time = 0.0;
        for n in [200usize, 600, 1000] {
            let cfg = StudyConfig {
                method: SaMethod::Vbd { n, k_active: 8 },
                sampler: kind,
                // one bucket per merge group = the reuse-tree maximum
                algorithm: FineAlgorithm::Trtma(TrtmaOptions::new(1)),
                ..StudyConfig::default()
            };
            let prepared = prepare(&cfg);
            let (plan, d) = time_once(|| prepared.plan(&cfg));
            cells.push(format!("{:.2}%", plan.fine_reuse() * 100.0));
            last_time = d.as_secs_f64();
        }
        cells.push(fmt_secs(last_time));
        t.row(&cells);
    }
    t.print("Table 4 — maximum fine-grain reuse potential, VBD (10x sample evals)");
}
