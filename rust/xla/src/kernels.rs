//! Pure-Rust port of the nine workflow task kernels.
//!
//! This is a line-for-line port of `python/compile/model.py` (whose jnp
//! oracles live in `python/compile/kernels/ref.py`): stain normalization,
//! the seven fine-grain segmentation tasks `t1`..`t7`, and the `cmp`
//! mask-comparison task. The propagation operators (morphological
//! reconstruction, hole filling, connected components, watershed) are the
//! same IWPP fixpoint sweeps the Pallas kernels implement, iterated to
//! convergence on the CPU.
//!
//! Semantics must match the JAX model exactly where it matters for the
//! paper experiments: identical masks for identical inputs, monotone
//! responses to the Table-1 parameters, and deterministic output across
//! re-executions.
//!
//! NOTE: when changing any kernel's semantics, also bump the
//! `sha256_16` tags in `rust/artifacts/manifest.json` (currently
//! `native-stub-r1`) — the cross-study cache folds the artifact
//! fingerprint into its keys, and stale persistent entries are only
//! invalidated when that fingerprint moves.

/// Maximum sweeps for any fixpoint loop (safety net; convergence exits
/// earlier — propagation distance is bounded by the tile diagonal).
const MAX_SWEEPS: usize = 4096;

/// Erosion depth levels tracked for watershed seeding.
pub const DEPTH_LEVELS: usize = 16;

/// Normalization targets (model.py `_NORM_MEAN` / `_NORM_STD`).
const NORM_MEAN: f32 = 210.0;
const NORM_STD: f32 = 40.0;

/// h-maxima suppression height for watershed seeding.
const SEED_H: f32 = 2.0;

/// Fixed h-dome height for candidate extraction (t2).
const DOME_H: f32 = 100.0;

/// A row-major 2-D f32 image plane.
#[derive(Clone, Debug, PartialEq)]
pub struct Grid {
    pub data: Vec<f32>,
    pub h: usize,
    pub w: usize,
}

impl Grid {
    pub fn new(data: Vec<f32>, h: usize, w: usize) -> Self {
        assert_eq!(data.len(), h * w, "grid data length mismatch");
        Self { data, h, w }
    }

    pub fn filled(v: f32, h: usize, w: usize) -> Self {
        Self { data: vec![v; h * w], h, w }
    }

    #[inline]
    fn at(&self, y: usize, x: usize) -> f32 {
        self.data[y * self.w + x]
    }

    #[inline]
    fn set(&mut self, y: usize, x: usize, v: f32) {
        self.data[y * self.w + x] = v;
    }

    fn map(&self, f: impl Fn(f32) -> f32) -> Grid {
        Grid { data: self.data.iter().map(|&v| f(v)).collect(), h: self.h, w: self.w }
    }

    fn zip(&self, other: &Grid, f: impl Fn(f32, f32) -> f32) -> Grid {
        debug_assert_eq!((self.h, self.w), (other.h, other.w));
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect();
        Grid { data, h: self.h, w: self.w }
    }
}

/// What a task produces: three chain planes, or the cmp metrics triple.
pub enum TaskOutput {
    Planes([Grid; 3]),
    Metrics([f32; 3]),
}

// ---------------------------------------------------------------------------
// neighborhood sweeps (the L1 kernels)
// ---------------------------------------------------------------------------

/// Neighborhood extremum including the center pixel; out-of-bounds
/// neighbors are skipped (equivalent to the oracles' ±inf padding).
fn nbr_ext(x: &Grid, conn8: bool, ext: impl Fn(f32, f32) -> f32) -> Grid {
    let (h, w) = (x.h, x.w);
    let mut out = Grid::filled(0.0, h, w);
    for y in 0..h {
        for c in 0..w {
            let mut v = x.at(y, c);
            if y > 0 {
                v = ext(v, x.at(y - 1, c));
            }
            if y + 1 < h {
                v = ext(v, x.at(y + 1, c));
            }
            if c > 0 {
                v = ext(v, x.at(y, c - 1));
            }
            if c + 1 < w {
                v = ext(v, x.at(y, c + 1));
            }
            if conn8 {
                if y > 0 && c > 0 {
                    v = ext(v, x.at(y - 1, c - 1));
                }
                if y > 0 && c + 1 < w {
                    v = ext(v, x.at(y - 1, c + 1));
                }
                if y + 1 < h && c > 0 {
                    v = ext(v, x.at(y + 1, c - 1));
                }
                if y + 1 < h && c + 1 < w {
                    v = ext(v, x.at(y + 1, c + 1));
                }
            }
            out.set(y, c, v);
        }
    }
    out
}

fn nbr_max(x: &Grid, conn8: bool) -> Grid {
    nbr_ext(x, conn8, f32::max)
}

fn nbr_min(x: &Grid, conn8: bool) -> Grid {
    nbr_ext(x, conn8, f32::min)
}

/// One reconstruction-by-dilation sweep: min(dilate(marker), mask).
fn recon_sweep(marker: &Grid, mask: &Grid, conn8: bool) -> Grid {
    nbr_max(marker, conn8).zip(mask, f32::min)
}

/// One label-growing sweep: unlabeled active pixels take the max
/// neighboring label.
fn label_sweep(labels: &Grid, active: &Grid, conn8: bool) -> Grid {
    let nbr = nbr_max(labels, conn8);
    let mut out = labels.clone();
    for i in 0..out.data.len() {
        if out.data[i] == 0.0 && active.data[i] > 0.5 {
            out.data[i] = nbr.data[i];
        }
    }
    out
}

/// Iterate a monotone sweep until the image stops changing.
fn fixpoint(init: Grid, sweep: impl Fn(&Grid) -> Grid) -> Grid {
    let mut cur = init;
    for _ in 0..MAX_SWEEPS {
        let nxt = sweep(&cur);
        if nxt.data == cur.data {
            return nxt;
        }
        cur = nxt;
    }
    cur
}

// ---------------------------------------------------------------------------
// propagation operators
// ---------------------------------------------------------------------------

/// Greyscale morphological reconstruction by dilation (IWPP fixpoint).
fn morph_reconstruct(marker: &Grid, mask: &Grid, conn8: bool) -> Grid {
    let init = marker.zip(mask, f32::min);
    fixpoint(init, |m| recon_sweep(m, mask, conn8))
}

/// Fill holes: background not reachable from the border becomes object.
fn fill_holes(binary: &Grid, conn8: bool) -> Grid {
    let (h, w) = (binary.h, binary.w);
    let comp = binary.map(|v| 1.0 - v);
    let mut marker = Grid::filled(0.0, h, w);
    for y in 0..h {
        for c in 0..w {
            if y == 0 || y == h - 1 || c == 0 || c == w - 1 {
                marker.set(y, c, comp.at(y, c));
            }
        }
    }
    let outside = fixpoint(marker, |m| recon_sweep(m, &comp, conn8));
    let mut out = Grid::filled(0.0, h, w);
    for i in 0..out.data.len() {
        let keep = if outside.data[i] > 0.5 { 0.0 } else { 1.0 };
        out.data[i] = keep * binary.data[i].max(comp.data[i]);
    }
    out
}

/// Label connected components with the min linear index + 1 (0 = bg),
/// via min-propagation under a per-pixel ceiling (negated-label trick:
/// shares the reconstruction sweep kernel).
fn connected_components(mask: &Grid, conn8: bool) -> Grid {
    let (h, w) = (mask.h, mask.w);
    let big = (h * w) as f32 + 2.0;
    let mut neg = Grid::filled(0.0, h, w);
    let mut ceil = Grid::filled(0.0, h, w);
    for i in 0..neg.data.len() {
        if mask.data[i] > 0.5 {
            neg.data[i] = -(i as f32 + 1.0);
            ceil.data[i] = 0.0;
        } else {
            neg.data[i] = -big;
            ceil.data[i] = -big;
        }
    }
    let out = fixpoint(neg, |m| recon_sweep(m, &ceil, conn8));
    let mut labels = Grid::filled(0.0, h, w);
    for i in 0..labels.data.len() {
        if mask.data[i] > 0.5 {
            labels.data[i] = -out.data[i];
        }
    }
    labels
}

/// Per-pixel size of the pixel's component (0 on background).
fn component_sizes(labels: &Grid) -> Grid {
    let n = labels.h * labels.w + 2;
    let mut counts = vec![0.0f32; n];
    for &l in &labels.data {
        counts[(l.max(0.0) as usize).min(n - 1)] += 1.0;
    }
    let mut out = Grid::filled(0.0, labels.h, labels.w);
    for i in 0..out.data.len() {
        let l = labels.data[i];
        if l > 0.5 {
            out.data[i] = counts[(l as usize).min(n - 1)];
        }
    }
    out
}

/// Per-pixel max of `values` over the pixel's component (0 on bg).
fn component_max(labels: &Grid, values: &Grid) -> Grid {
    let n = labels.h * labels.w + 2;
    let mut maxes = vec![f32::NEG_INFINITY; n];
    for i in 0..labels.data.len() {
        let slot = (labels.data[i].max(0.0) as usize).min(n - 1);
        maxes[slot] = maxes[slot].max(values.data[i]);
    }
    let mut out = Grid::filled(0.0, labels.h, labels.w);
    for i in 0..out.data.len() {
        let l = labels.data[i];
        if l > 0.5 {
            out.data[i] = maxes[(l as usize).min(n - 1)];
        }
    }
    out
}

/// Drop connected components with size outside [min_size, max_size].
fn area_filter(mask: &Grid, min_size: f32, max_size: f32, conn8: bool) -> Grid {
    let labels = connected_components(mask, conn8);
    let sizes = component_sizes(&labels);
    let mut out = Grid::filled(0.0, mask.h, mask.w);
    for i in 0..out.data.len() {
        if (min_size..=max_size).contains(&sizes.data[i]) {
            out.data[i] = mask.data[i];
        }
    }
    out
}

/// Number of 8-conn erosions each pixel survives, + 1 on the mask.
fn erosion_depth(mask: &Grid) -> Grid {
    let mut cur = mask.clone();
    let mut depth = mask.clone();
    for _ in 0..DEPTH_LEVELS - 1 {
        cur = nbr_min(&cur, true);
        for i in 0..depth.data.len() {
            depth.data[i] += cur.data[i];
        }
    }
    depth
}

/// Seeded watershed by level-ordered label growing (dense IWPP form).
/// Seeds are the h-maxima of `depth` (h = SEED_H); low-relief components
/// seed from their peak plateau. See model.py `watershed` for the full
/// rationale.
fn watershed(mask: &Grid, depth: &Grid, conn8: bool) -> Grid {
    let (h, w) = (mask.h, mask.w);
    let marker = depth.map(|v| (v - SEED_H).max(0.0));
    let hrecon = morph_reconstruct(&marker, depth, true);
    let comp = connected_components(mask, true);
    let peak = component_max(&comp, depth);

    let mut seed_mask = Grid::filled(0.0, h, w);
    for i in 0..seed_mask.data.len() {
        let inside = mask.data[i] > 0.5;
        let hseed = depth.data[i] - hrecon.data[i] >= SEED_H && inside;
        let lowseed = peak.data[i] < SEED_H && depth.data[i] >= peak.data[i] && inside;
        if hseed || lowseed {
            seed_mask.data[i] = 1.0;
        }
    }
    let mut labels = connected_components(&seed_mask, true);

    for i in 0..DEPTH_LEVELS {
        let level = (DEPTH_LEVELS - i) as f32;
        let mut active = Grid::filled(0.0, h, w);
        for j in 0..active.data.len() {
            if depth.data[j] >= level && mask.data[j] > 0.5 {
                active.data[j] = 1.0;
            }
        }
        labels = fixpoint(labels, |l| label_sweep(l, &active, conn8));
    }
    for i in 0..labels.data.len() {
        if mask.data[i] <= 0.5 {
            labels.data[i] = 0.0;
        }
    }
    labels
}

// ---------------------------------------------------------------------------
// the workflow tasks
// ---------------------------------------------------------------------------

fn normalize_channel(x: &Grid) -> Grid {
    let n = x.data.len() as f64;
    let mu = x.data.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = x.data.iter().map(|&v| (v as f64 - mu) * (v as f64 - mu)).sum::<f64>() / n;
    let sd = var.sqrt() as f32 + 1e-6;
    let mu = mu as f32;
    x.map(|v| ((v - mu) / sd * NORM_STD + NORM_MEAN).clamp(0.0, 255.0))
}

fn task_norm(a: &Grid, b: &Grid, c: &Grid) -> [Grid; 3] {
    [normalize_channel(a), normalize_channel(b), normalize_channel(c)]
}

fn task_t1(r: &Grid, g: &Grid, bl: &Grid, p: &[f32]) -> [Grid; 3] {
    let (bb, gg, rr, t1, t2) = (par(p, 0), par(p, 1), par(p, 2), par(p, 3), par(p, 4));
    let (h, w) = (r.h, r.w);
    let mut grey = Grid::filled(0.0, h, w);
    let mut fg = Grid::filled(0.0, h, w);
    for i in 0..grey.data.len() {
        let (rv, gv, bv) = (r.data[i], g.data[i], bl.data[i]);
        let background = rv > bb && gv > gg && bv > rr;
        let rbc = (rv + 1.0) / (gv + 1.0) > t1 && (rv + 1.0) / (bv + 1.0) > t2;
        grey.data[i] = 255.0 - (0.299 * rv + 0.587 * gv + 0.114 * bv);
        fg.data[i] = if background || rbc { 0.0 } else { 1.0 };
    }
    let zeros = Grid::filled(0.0, h, w);
    [grey, fg, zeros]
}

fn task_t2(grey: &Grid, fg: &Grid, p: &[f32]) -> [Grid; 3] {
    let (g1, rc) = (par(p, 0), par(p, 1));
    let marker = grey.zip(fg, |gv, fv| (gv - DOME_H).max(0.0) * fv);
    let recon = morph_reconstruct(&marker, grey, rc >= 8.0);
    let domes = grey.zip(&recon, |gv, rv| gv - rv).zip(fg, |d, fv| d * fv);
    let cand = domes.map(|d| if d >= g1 { 1.0 } else { 0.0 });
    [grey.clone(), cand, domes]
}

fn task_t3(grey: &Grid, cand: &Grid, domes: &Grid, p: &[f32]) -> [Grid; 3] {
    let fh = par(p, 0);
    [grey.clone(), fill_holes(cand, fh >= 8.0), domes.clone()]
}

fn task_t4(grey: &Grid, filled: &Grid, domes: &Grid, p: &[f32]) -> [Grid; 3] {
    let (g2, min_s, max_s) = (par(p, 0), par(p, 1), par(p, 2));
    let labels = connected_components(filled, true);
    let sizes = component_sizes(&labels);
    let peak = component_max(&labels, domes);
    let mut kept = Grid::filled(0.0, filled.h, filled.w);
    for i in 0..kept.data.len() {
        let keep = (min_s..=max_s).contains(&sizes.data[i]) && peak.data[i] >= g2;
        if keep {
            kept.data[i] = filled.data[i];
        }
    }
    [grey.clone(), kept, domes.clone()]
}

fn task_t5(grey: &Grid, kept: &Grid, p: &[f32]) -> [Grid; 3] {
    let min_spl = par(p, 0);
    let mask = area_filter(kept, min_spl, 1e9, true);
    let depth = erosion_depth(&mask);
    [grey.clone(), mask, depth]
}

fn task_t6(grey: &Grid, mask: &Grid, depth: &Grid, p: &[f32]) -> [Grid; 3] {
    let wconn = par(p, 0);
    let labels = watershed(mask, depth, wconn >= 8.0);
    let seg = labels.map(|l| if l > 0.5 { 1.0 } else { 0.0 });
    [grey.clone(), seg, labels]
}

fn task_t7(grey: &Grid, seg: &Grid, labels: &Grid, p: &[f32]) -> [Grid; 3] {
    let (min_ss, max_ss) = (par(p, 0), par(p, 1));
    let sizes = component_sizes(labels);
    let mut fin = Grid::filled(0.0, seg.h, seg.w);
    let mut lab = Grid::filled(0.0, seg.h, seg.w);
    for i in 0..fin.data.len() {
        let keep = (min_ss..=max_ss).contains(&sizes.data[i]) && seg.data[i] > 0.5;
        if keep {
            fin.data[i] = 1.0;
            lab.data[i] = labels.data[i];
        }
    }
    [grey.clone(), fin, lab]
}

fn task_cmp(b: &Grid, reference: &Grid) -> [f32; 3] {
    let mut inter = 0.0f64;
    let mut sm = 0.0f64;
    let mut sr = 0.0f64;
    let mut diff = 0.0f64;
    for i in 0..b.data.len() {
        let m = if b.data[i] > 0.5 { 1.0f64 } else { 0.0 };
        let r = if reference.data[i] > 0.5 { 1.0f64 } else { 0.0 };
        inter += m * r;
        sm += m;
        sr += r;
        diff += (m - r).abs();
    }
    let union = sm + sr - inter;
    let dice = (2.0 * inter + 1e-6) / (sm + sr + 1e-6);
    let jacc = (inter + 1e-6) / (union + 1e-6);
    let mean_diff = diff / b.data.len().max(1) as f64;
    [dice as f32, jacc as f32, mean_diff as f32]
}

#[inline]
fn par(p: &[f32], i: usize) -> f32 {
    p.get(i).copied().unwrap_or(0.0)
}

// ---------------------------------------------------------------------------
// batched execution: one call, B lanes
// ---------------------------------------------------------------------------
//
// Sensitivity-analysis studies execute the *same task* over many nearby
// parameter sets; the fine-grain batching layer stacks up to B of those
// evaluations into one call and vectorizes the per-pixel inner loops
// across the batch. Data is lane-interleaved (`data[pixel * b + lane]`),
// so the innermost loop of every sweep runs over `b` contiguous f32s —
// bounds checks and index arithmetic amortize over the batch and LLVM
// autovectorizes the lane loop.
//
// **Equivalence contract.** Each lane of a batched task must produce
// bit-identical output to the scalar kernel on the same inputs: every
// batched operator mirrors its scalar counterpart operation-for-
// operation in the same order (f32 min/max are exact; the f64
// normalization sums accumulate in the same pixel order), and the
// fixpoint loops apply the same sweeps per lane — a lane is frozen at
// the first sweep that leaves it unchanged, exactly where the scalar
// `fixpoint` stops. `batched_chain_matches_scalar_lanes` enforces this.

/// A batch of B same-shaped planes, lane-interleaved:
/// `data[(y * w + x) * b + lane]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Batch {
    data: Vec<f32>,
    h: usize,
    w: usize,
    b: usize,
}

impl Batch {
    fn filled(v: f32, h: usize, w: usize, b: usize) -> Batch {
        Batch { data: vec![v; h * w * b], h, w, b }
    }

    /// Interleave one plane per lane (all planes must share a shape).
    fn from_lanes(planes: &[&Grid]) -> Batch {
        let b = planes.len();
        let (h, w) = (planes[0].h, planes[0].w);
        let mut data = vec![0.0f32; h * w * b];
        for (l, p) in planes.iter().enumerate() {
            for (i, &v) in p.data.iter().enumerate() {
                data[i * b + l] = v;
            }
        }
        Batch { data, h, w, b }
    }

    /// Extract one lane as a scalar grid.
    fn lane(&self, l: usize) -> Grid {
        let mut out = Grid::filled(0.0, self.h, self.w);
        for i in 0..self.h * self.w {
            out.data[i] = self.data[i * self.b + l];
        }
        out
    }

    fn map(&self, f: impl Fn(f32) -> f32) -> Batch {
        Batch {
            data: self.data.iter().map(|&v| f(v)).collect(),
            h: self.h,
            w: self.w,
            b: self.b,
        }
    }

    fn zip(&self, other: &Batch, f: impl Fn(f32, f32) -> f32) -> Batch {
        debug_assert_eq!((self.h, self.w, self.b), (other.h, other.w, other.b));
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect();
        Batch { data, h: self.h, w: self.w, b: self.b }
    }

    /// A new batch holding only the given lanes, in the given order.
    fn select_lanes(&self, lanes: &[usize]) -> Batch {
        let nb = lanes.len();
        let mut data = vec![0.0f32; self.h * self.w * nb];
        for i in 0..self.h * self.w {
            let src = &self.data[i * self.b..(i + 1) * self.b];
            let dst = &mut data[i * nb..(i + 1) * nb];
            for (j, &l) in lanes.iter().enumerate() {
                dst[j] = src[l];
            }
        }
        Batch { data, h: self.h, w: self.w, b: nb }
    }

    /// Write `src` lane `j` into `self` lane `lanes[j]` for every j.
    fn scatter_lanes(&mut self, src: &Batch, lanes: &[usize]) {
        debug_assert_eq!(src.b, lanes.len());
        for i in 0..self.h * self.w {
            for (j, &l) in lanes.iter().enumerate() {
                self.data[i * self.b + l] = src.data[i * src.b + j];
            }
        }
    }

    /// Copy `src` lane `src_lane` into `self` lane `dst_lane`.
    fn copy_lane(&mut self, dst_lane: usize, src: &Batch, src_lane: usize) {
        for i in 0..self.h * self.w {
            self.data[i * self.b + dst_lane] = src.data[i * src.b + src_lane];
        }
    }
}

/// Per-lane "did any pixel change" between two equally-shaped batches.
fn changed_lanes(a: &Batch, b: &Batch) -> Vec<bool> {
    let mut ch = vec![false; a.b];
    for (ca, cb) in a.data.chunks_exact(a.b).zip(b.data.chunks_exact(b.b)) {
        for l in 0..a.b {
            if ca[l] != cb[l] {
                ch[l] = true;
            }
        }
    }
    ch
}

/// Batched neighborhood extremum — the vectorized form of [`nbr_ext`]:
/// neighbors are applied in the same order (up, down, left, right, then
/// the four diagonals), with the innermost loop running over the `b`
/// contiguous lanes of each pixel.
fn nbr_ext_b(x: &Batch, conn8: bool, ext: impl Fn(f32, f32) -> f32 + Copy) -> Batch {
    let (h, w, b) = (x.h, x.w, x.b);
    let mut out = x.clone(); // start from the center values
    let row = w * b;
    for y in 0..h {
        for c in 0..w {
            let d = (y * w + c) * b;
            let mut pull = |s: usize| {
                let dst = &mut out.data[d..d + b];
                let src = &x.data[s..s + b];
                for (dv, &sv) in dst.iter_mut().zip(src) {
                    *dv = ext(*dv, sv);
                }
            };
            if y > 0 {
                pull(d - row);
            }
            if y + 1 < h {
                pull(d + row);
            }
            if c > 0 {
                pull(d - b);
            }
            if c + 1 < w {
                pull(d + b);
            }
            if conn8 {
                if y > 0 && c > 0 {
                    pull(d - row - b);
                }
                if y > 0 && c + 1 < w {
                    pull(d - row + b);
                }
                if y + 1 < h && c > 0 {
                    pull(d + row - b);
                }
                if y + 1 < h && c + 1 < w {
                    pull(d + row + b);
                }
            }
        }
    }
    out
}

/// One batched reconstruction-by-dilation sweep (cf. [`recon_sweep`]).
fn recon_sweep_b(marker: &Batch, mask: &Batch, conn8: bool) -> Batch {
    nbr_ext_b(marker, conn8, f32::max).zip(mask, f32::min)
}

/// One batched label-growing sweep (cf. [`label_sweep`]).
fn label_sweep_b(labels: &Batch, active: &Batch, conn8: bool) -> Batch {
    let nbr = nbr_ext_b(labels, conn8, f32::max);
    let mut out = labels.clone();
    for i in 0..out.data.len() {
        if out.data[i] == 0.0 && active.data[i] > 0.5 {
            out.data[i] = nbr.data[i];
        }
    }
    out
}

/// Batched monotone fixpoint with per-lane convergence: every loop
/// iteration applies one sweep to all still-changing lanes; a lane is
/// frozen into the result at the first sweep that leaves it unchanged
/// (identical to where the scalar [`fixpoint`] stops for that lane).
/// Converged lanes are *compacted out* so slow lanes do not drag the
/// batch — the sweep cost tracks each lane's own convergence distance.
/// `ctx` batches (masks, activity planes) are compacted in sync and
/// handed back to the sweep alongside the iterate.
fn fixpoint_b(init: Batch, ctx: Vec<Batch>, sweep: impl Fn(&Batch, &[Batch]) -> Batch) -> Batch {
    let b = init.b;
    let mut result = Batch::filled(0.0, init.h, init.w, b);
    let mut live: Vec<usize> = (0..b).collect();
    let mut cur = init;
    let mut ctx = ctx;
    for _ in 0..MAX_SWEEPS {
        let nxt = sweep(&cur, &ctx);
        let changed = changed_lanes(&cur, &nxt);
        if changed.iter().all(|&c| c) {
            cur = nxt;
            continue;
        }
        let keep: Vec<usize> = (0..cur.b).filter(|&i| changed[i]).collect();
        for i in 0..cur.b {
            if !changed[i] {
                result.copy_lane(live[i], &nxt, i);
            }
        }
        if keep.is_empty() {
            return result;
        }
        live = keep.iter().map(|&i| live[i]).collect();
        cur = nxt.select_lanes(&keep);
        for c in ctx.iter_mut() {
            *c = c.select_lanes(&keep);
        }
    }
    for (i, &orig) in live.iter().enumerate() {
        result.copy_lane(orig, &cur, i);
    }
    result
}

/// Batched greyscale morphological reconstruction (cf.
/// [`morph_reconstruct`]).
fn morph_reconstruct_b(marker: &Batch, mask: &Batch, conn8: bool) -> Batch {
    let init = marker.zip(mask, f32::min);
    fixpoint_b(init, vec![mask.clone()], move |m, ctx| recon_sweep_b(m, &ctx[0], conn8))
}

/// Batched hole filling (cf. [`fill_holes`]).
fn fill_holes_b(binary: &Batch, conn8: bool) -> Batch {
    let (h, w, b) = (binary.h, binary.w, binary.b);
    let comp = binary.map(|v| 1.0 - v);
    let mut marker = Batch::filled(0.0, h, w, b);
    for y in 0..h {
        for c in 0..w {
            if y == 0 || y == h - 1 || c == 0 || c == w - 1 {
                let d = (y * w + c) * b;
                marker.data[d..d + b].copy_from_slice(&comp.data[d..d + b]);
            }
        }
    }
    let outside =
        fixpoint_b(marker, vec![comp.clone()], move |m, ctx| recon_sweep_b(m, &ctx[0], conn8));
    let mut out = Batch::filled(0.0, h, w, b);
    for i in 0..out.data.len() {
        let keep = if outside.data[i] > 0.5 { 0.0 } else { 1.0 };
        out.data[i] = keep * binary.data[i].max(comp.data[i]);
    }
    out
}

/// Batched connected components (cf. [`connected_components`]).
fn connected_components_b(mask: &Batch, conn8: bool) -> Batch {
    let (h, w, b) = (mask.h, mask.w, mask.b);
    let big = (h * w) as f32 + 2.0;
    let mut neg = Batch::filled(0.0, h, w, b);
    let mut ceil = Batch::filled(0.0, h, w, b);
    for i in 0..h * w {
        for l in 0..b {
            let j = i * b + l;
            if mask.data[j] > 0.5 {
                neg.data[j] = -(i as f32 + 1.0);
                ceil.data[j] = 0.0;
            } else {
                neg.data[j] = -big;
                ceil.data[j] = -big;
            }
        }
    }
    let rec = fixpoint_b(neg, vec![ceil], move |m, ctx| recon_sweep_b(m, &ctx[0], conn8));
    let mut labels = Batch::filled(0.0, h, w, b);
    for j in 0..labels.data.len() {
        if mask.data[j] > 0.5 {
            labels.data[j] = -rec.data[j];
        }
    }
    labels
}

/// Batched per-component pixel counts (cf. [`component_sizes`]). The
/// histogram passes run lane-by-lane in pixel order, matching the scalar
/// accumulation exactly; they are O(HW) per lane and far off the
/// sweep-dominated critical path.
fn component_sizes_b(labels: &Batch) -> Batch {
    let (hw, b) = (labels.h * labels.w, labels.b);
    let n = hw + 2;
    let mut out = Batch::filled(0.0, labels.h, labels.w, b);
    for l in 0..b {
        let mut counts = vec![0.0f32; n];
        for i in 0..hw {
            let v = labels.data[i * b + l];
            counts[(v.max(0.0) as usize).min(n - 1)] += 1.0;
        }
        for i in 0..hw {
            let v = labels.data[i * b + l];
            if v > 0.5 {
                out.data[i * b + l] = counts[(v as usize).min(n - 1)];
            }
        }
    }
    out
}

/// Batched per-component max of `values` (cf. [`component_max`]).
fn component_max_b(labels: &Batch, values: &Batch) -> Batch {
    let (hw, b) = (labels.h * labels.w, labels.b);
    let n = hw + 2;
    let mut out = Batch::filled(0.0, labels.h, labels.w, b);
    for l in 0..b {
        let mut maxes = vec![f32::NEG_INFINITY; n];
        for i in 0..hw {
            let slot = (labels.data[i * b + l].max(0.0) as usize).min(n - 1);
            maxes[slot] = maxes[slot].max(values.data[i * b + l]);
        }
        for i in 0..hw {
            let v = labels.data[i * b + l];
            if v > 0.5 {
                out.data[i * b + l] = maxes[(v as usize).min(n - 1)];
            }
        }
    }
    out
}

/// Batched area filter with per-lane size bounds (cf. [`area_filter`]).
fn area_filter_b(mask: &Batch, min_size: &[f32], max_size: &[f32], conn8: bool) -> Batch {
    let labels = connected_components_b(mask, conn8);
    let sizes = component_sizes_b(&labels);
    let mut out = Batch::filled(0.0, mask.h, mask.w, mask.b);
    let b = mask.b;
    for i in 0..mask.h * mask.w {
        for l in 0..b {
            let j = i * b + l;
            if (min_size[l]..=max_size[l]).contains(&sizes.data[j]) {
                out.data[j] = mask.data[j];
            }
        }
    }
    out
}

/// Batched erosion depth (cf. [`erosion_depth`]; fixed sweep count, no
/// convergence tracking needed).
fn erosion_depth_b(mask: &Batch) -> Batch {
    let mut cur = mask.clone();
    let mut depth = mask.clone();
    for _ in 0..DEPTH_LEVELS - 1 {
        cur = nbr_ext_b(&cur, true, f32::min);
        for i in 0..depth.data.len() {
            depth.data[i] += cur.data[i];
        }
    }
    depth
}

/// Batched seeded watershed (cf. [`watershed`]); `conn8` is the label-
/// growing connectivity, uniform for all lanes of the (sub-)batch.
fn watershed_b(mask: &Batch, depth: &Batch, conn8: bool) -> Batch {
    let (h, w, b) = (mask.h, mask.w, mask.b);
    let marker = depth.map(|v| (v - SEED_H).max(0.0));
    let hrecon = morph_reconstruct_b(&marker, depth, true);
    let comp = connected_components_b(mask, true);
    let peak = component_max_b(&comp, depth);

    let mut seed_mask = Batch::filled(0.0, h, w, b);
    for j in 0..seed_mask.data.len() {
        let inside = mask.data[j] > 0.5;
        let hseed = depth.data[j] - hrecon.data[j] >= SEED_H && inside;
        let lowseed = peak.data[j] < SEED_H && depth.data[j] >= peak.data[j] && inside;
        if hseed || lowseed {
            seed_mask.data[j] = 1.0;
        }
    }
    let mut labels = connected_components_b(&seed_mask, true);

    for i in 0..DEPTH_LEVELS {
        let level = (DEPTH_LEVELS - i) as f32;
        let mut active = Batch::filled(0.0, h, w, b);
        for j in 0..active.data.len() {
            if depth.data[j] >= level && mask.data[j] > 0.5 {
                active.data[j] = 1.0;
            }
        }
        labels = fixpoint_b(labels, vec![active], move |l, ctx| label_sweep_b(l, &ctx[0], conn8));
    }
    for j in 0..labels.data.len() {
        if mask.data[j] <= 0.5 {
            labels.data[j] = 0.0;
        }
    }
    labels
}

/// Batched stain normalization of one channel: per-lane f64 mean and
/// variance accumulated in the scalar [`normalize_channel`]'s pixel
/// order, so every lane matches the scalar output bit-for-bit.
fn normalize_channel_b(x: &Batch) -> Batch {
    let b = x.b;
    let n = (x.h * x.w) as f64;
    let mut mu = vec![0.0f64; b];
    for chunk in x.data.chunks_exact(b) {
        for l in 0..b {
            mu[l] += chunk[l] as f64;
        }
    }
    for m in mu.iter_mut() {
        *m /= n;
    }
    let mut var = vec![0.0f64; b];
    for chunk in x.data.chunks_exact(b) {
        for l in 0..b {
            let d = chunk[l] as f64 - mu[l];
            var[l] += d * d;
        }
    }
    let sd: Vec<f32> = var.iter().map(|&v| (v / n).sqrt() as f32 + 1e-6).collect();
    let muf: Vec<f32> = mu.iter().map(|&m| m as f32).collect();
    let mut out = x.clone();
    for chunk in out.data.chunks_exact_mut(b) {
        for l in 0..b {
            chunk[l] = ((chunk[l] - muf[l]) / sd[l] * NORM_STD + NORM_MEAN).clamp(0.0, 255.0);
        }
    }
    out
}

/// Per-lane value of parameter `i` across the batch.
fn lane_params(params: &[&[f32]], i: usize) -> Vec<f32> {
    params.iter().map(|p| par(p, i)).collect()
}

/// Run `f` once per connectivity group (lanes whose connectivity flag
/// agrees), reassembling one output batch. The uniform case runs on the
/// full batch with no lane copies.
fn run_conn_grouped(
    inputs: &[&Batch],
    conn8: &[bool],
    f: impl Fn(&[&Batch], bool) -> Batch,
) -> Batch {
    let b = conn8.len();
    if conn8.iter().all(|&c| c == conn8[0]) {
        return f(inputs, conn8[0]);
    }
    let (h, w) = (inputs[0].h, inputs[0].w);
    let mut out = Batch::filled(0.0, h, w, b);
    for flag in [false, true] {
        let lanes: Vec<usize> = (0..b).filter(|&l| conn8[l] == flag).collect();
        if lanes.is_empty() {
            continue;
        }
        let sel: Vec<Batch> = inputs.iter().map(|x| x.select_lanes(&lanes)).collect();
        let refs: Vec<&Batch> = sel.iter().collect();
        out.scatter_lanes(&f(&refs, flag), &lanes);
    }
    out
}

fn task_norm_b(a: &Batch, b: &Batch, c: &Batch) -> [Batch; 3] {
    [normalize_channel_b(a), normalize_channel_b(b), normalize_channel_b(c)]
}

fn task_t1_b(r: &Batch, g: &Batch, bl: &Batch, params: &[&[f32]]) -> [Batch; 3] {
    let (bb, gg, rr) = (lane_params(params, 0), lane_params(params, 1), lane_params(params, 2));
    let (t1, t2) = (lane_params(params, 3), lane_params(params, 4));
    let (h, w, b) = (r.h, r.w, r.b);
    let mut grey = Batch::filled(0.0, h, w, b);
    let mut fg = Batch::filled(0.0, h, w, b);
    for i in 0..h * w {
        for l in 0..b {
            let j = i * b + l;
            let (rv, gv, bv) = (r.data[j], g.data[j], bl.data[j]);
            let background = rv > bb[l] && gv > gg[l] && bv > rr[l];
            let rbc = (rv + 1.0) / (gv + 1.0) > t1[l] && (rv + 1.0) / (bv + 1.0) > t2[l];
            grey.data[j] = 255.0 - (0.299 * rv + 0.587 * gv + 0.114 * bv);
            fg.data[j] = if background || rbc { 0.0 } else { 1.0 };
        }
    }
    let zeros = Batch::filled(0.0, h, w, b);
    [grey, fg, zeros]
}

fn task_t2_b(grey: &Batch, fg: &Batch, params: &[&[f32]]) -> [Batch; 3] {
    let g1 = lane_params(params, 0);
    let rc = lane_params(params, 1);
    let marker = grey.zip(fg, |gv, fv| (gv - DOME_H).max(0.0) * fv);
    let conn: Vec<bool> = rc.iter().map(|&v| v >= 8.0).collect();
    let recon = run_conn_grouped(&[&marker, grey], &conn, |ins, c8| {
        morph_reconstruct_b(ins[0], ins[1], c8)
    });
    let domes = grey.zip(&recon, |gv, rv| gv - rv).zip(fg, |d, fv| d * fv);
    let b = grey.b;
    let mut cand = Batch::filled(0.0, grey.h, grey.w, b);
    for i in 0..grey.h * grey.w {
        for l in 0..b {
            let j = i * b + l;
            if domes.data[j] >= g1[l] {
                cand.data[j] = 1.0;
            }
        }
    }
    [grey.clone(), cand, domes]
}

fn task_t3_b(grey: &Batch, cand: &Batch, domes: &Batch, params: &[&[f32]]) -> [Batch; 3] {
    let fh = lane_params(params, 0);
    let conn: Vec<bool> = fh.iter().map(|&v| v >= 8.0).collect();
    let filled = run_conn_grouped(&[cand], &conn, |ins, c8| fill_holes_b(ins[0], c8));
    [grey.clone(), filled, domes.clone()]
}

fn task_t4_b(grey: &Batch, filled: &Batch, domes: &Batch, params: &[&[f32]]) -> [Batch; 3] {
    let (g2, min_s, max_s) =
        (lane_params(params, 0), lane_params(params, 1), lane_params(params, 2));
    let labels = connected_components_b(filled, true);
    let sizes = component_sizes_b(&labels);
    let peak = component_max_b(&labels, domes);
    let b = filled.b;
    let mut kept = Batch::filled(0.0, filled.h, filled.w, b);
    for i in 0..filled.h * filled.w {
        for l in 0..b {
            let j = i * b + l;
            let keep = (min_s[l]..=max_s[l]).contains(&sizes.data[j]) && peak.data[j] >= g2[l];
            if keep {
                kept.data[j] = filled.data[j];
            }
        }
    }
    [grey.clone(), kept, domes.clone()]
}

fn task_t5_b(grey: &Batch, kept: &Batch, params: &[&[f32]]) -> [Batch; 3] {
    let min_spl = lane_params(params, 0);
    let max = vec![1e9f32; kept.b];
    let mask = area_filter_b(kept, &min_spl, &max, true);
    let depth = erosion_depth_b(&mask);
    [grey.clone(), mask, depth]
}

fn task_t6_b(grey: &Batch, mask: &Batch, depth: &Batch, params: &[&[f32]]) -> [Batch; 3] {
    let wconn = lane_params(params, 0);
    let conn: Vec<bool> = wconn.iter().map(|&v| v >= 8.0).collect();
    let labels = run_conn_grouped(&[mask, depth], &conn, |ins, c8| watershed_b(ins[0], ins[1], c8));
    let seg = labels.map(|l| if l > 0.5 { 1.0 } else { 0.0 });
    [grey.clone(), seg, labels]
}

fn task_t7_b(grey: &Batch, seg: &Batch, labels: &Batch, params: &[&[f32]]) -> [Batch; 3] {
    let (min_ss, max_ss) = (lane_params(params, 0), lane_params(params, 1));
    let sizes = component_sizes_b(labels);
    let b = seg.b;
    let mut fin = Batch::filled(0.0, seg.h, seg.w, b);
    let mut lab = Batch::filled(0.0, seg.h, seg.w, b);
    for i in 0..seg.h * seg.w {
        for l in 0..b {
            let j = i * b + l;
            let keep = (min_ss[l]..=max_ss[l]).contains(&sizes.data[j]) && seg.data[j] > 0.5;
            if keep {
                fin.data[j] = 1.0;
                lab.data[j] = labels.data[j];
            }
        }
    }
    [grey.clone(), fin, lab]
}

/// Execute one chain task over a batch of B states × B parameter
/// vectors in a single call, vectorizing the per-pixel inner loops
/// across the batch. `states[i]` holds lane i's three input planes;
/// `params[i]` its (possibly short — missing entries read as 0) parameter
/// vector. Every lane's output is bit-identical to [`run_task`] on the
/// same inputs. `cmp` is not a chain task and is rejected.
pub fn run_task_batch(
    name: &str,
    states: &[[Grid; 3]],
    params: &[&[f32]],
) -> Result<Vec<[Grid; 3]>, String> {
    if states.is_empty() {
        return Ok(Vec::new());
    }
    if states.len() != params.len() {
        return Err(format!(
            "batch arity mismatch: {} states vs {} param vectors",
            states.len(),
            params.len()
        ));
    }
    let (h, w) = (states[0][0].h, states[0][0].w);
    for s in states {
        for p in s {
            if (p.h, p.w) != (h, w) {
                return Err("batch planes disagree on shape".into());
            }
        }
    }
    let a = Batch::from_lanes(&states.iter().map(|s| &s[0]).collect::<Vec<_>>());
    let b = Batch::from_lanes(&states.iter().map(|s| &s[1]).collect::<Vec<_>>());
    let c = Batch::from_lanes(&states.iter().map(|s| &s[2]).collect::<Vec<_>>());
    let out: [Batch; 3] = match name {
        "norm" => task_norm_b(&a, &b, &c),
        "t1" => task_t1_b(&a, &b, &c, params),
        "t2" => task_t2_b(&a, &b, params),
        "t3" => task_t3_b(&a, &b, &c, params),
        "t4" => task_t4_b(&a, &b, &c, params),
        "t5" => task_t5_b(&a, &b, params),
        "t6" => task_t6_b(&a, &b, &c, params),
        "t7" => task_t7_b(&a, &b, &c, params),
        other => return Err(format!("task `{other}` is not batchable")),
    };
    Ok((0..states.len()).map(|l| [out[0].lane(l), out[1].lane(l), out[2].lane(l)]).collect())
}

/// Execute one workflow task. Chain tasks take 3 planes, `cmp` takes 4
/// (state + reference mask); `params` is the padded parameter vector.
pub fn run_task(name: &str, planes: &[Grid], params: &[f32]) -> Result<TaskOutput, String> {
    let need = if name == "cmp" { 4 } else { 3 };
    if planes.len() != need {
        return Err(format!("task `{name}` needs {need} planes, got {}", planes.len()));
    }
    let (a, b, c) = (&planes[0], &planes[1], &planes[2]);
    let out = match name {
        "norm" => TaskOutput::Planes(task_norm(a, b, c)),
        "t1" => TaskOutput::Planes(task_t1(a, b, c, params)),
        "t2" => TaskOutput::Planes(task_t2(a, b, params)),
        "t3" => TaskOutput::Planes(task_t3(a, b, c, params)),
        "t4" => TaskOutput::Planes(task_t4(a, b, c, params)),
        "t5" => TaskOutput::Planes(task_t5(a, b, params)),
        "t6" => TaskOutput::Planes(task_t6(a, b, c, params)),
        "t7" => TaskOutput::Planes(task_t7(a, b, c, params)),
        "cmp" => TaskOutput::Metrics(task_cmp(b, &planes[3])),
        other => return Err(format!("unknown task `{other}`")),
    };
    Ok(out)
}

/// The chain task names in execution order.
pub const TASKS: [&str; 8] = ["norm", "t1", "t2", "t3", "t4", "t5", "t6", "t7"];

/// All task names this backend can execute (chain tasks + `cmp`).
pub fn known_task(name: &str) -> bool {
    name == "cmp" || TASKS.contains(&name)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid(rows: &[&[f32]]) -> Grid {
        let h = rows.len();
        let w = rows[0].len();
        Grid::new(rows.iter().flat_map(|r| r.iter().copied()).collect(), h, w)
    }

    #[test]
    fn fill_holes_closes_enclosed_background() {
        // 5x5 ring of ones with a hole in the middle
        let ring = grid(&[
            &[0., 0., 0., 0., 0.],
            &[0., 1., 1., 1., 0.],
            &[0., 1., 0., 1., 0.],
            &[0., 1., 1., 1., 0.],
            &[0., 0., 0., 0., 0.],
        ]);
        let filled = fill_holes(&ring, false);
        assert_eq!(filled.at(2, 2), 1.0, "hole must fill");
        assert_eq!(filled.at(0, 0), 0.0, "outside stays background");
        assert_eq!(filled.at(1, 1), 1.0, "object survives");
    }

    #[test]
    fn connected_components_labels_blobs_distinctly() {
        let two = grid(&[
            &[1., 1., 0., 0., 0.],
            &[1., 1., 0., 0., 0.],
            &[0., 0., 0., 1., 1.],
            &[0., 0., 0., 1., 1.],
        ]);
        let labels = connected_components(&two, true);
        let a = labels.at(0, 0);
        let b = labels.at(3, 4);
        assert!(a > 0.5 && b > 0.5);
        assert_ne!(a, b, "separate blobs get separate labels");
        assert_eq!(labels.at(0, 1), a, "blob is label-uniform");
        assert_eq!(labels.at(2, 0), 0.0, "background is 0");
        let sizes = component_sizes(&labels);
        assert_eq!(sizes.at(0, 0), 4.0);
        assert_eq!(sizes.at(2, 3), 4.0);
        assert_eq!(sizes.at(2, 0), 0.0);
    }

    #[test]
    fn reconstruction_never_exceeds_mask() {
        let mask = grid(&[&[5., 5., 1.], &[5., 9., 1.], &[1., 1., 1.]]);
        let marker = grid(&[&[0., 0., 0.], &[0., 7., 0.], &[0., 0., 0.]]);
        let rec = morph_reconstruct(&marker, &mask, true);
        for i in 0..rec.data.len() {
            assert!(rec.data[i] <= mask.data[i] + 1e-6);
        }
        // the 7-marker dilates through the 5-plateau but is capped by it
        assert_eq!(rec.at(0, 0), 5.0);
        assert_eq!(rec.at(1, 1), 7.0);
        assert_eq!(rec.at(2, 2), 1.0);
    }

    #[test]
    fn self_compare_is_perfect() {
        let m = grid(&[&[1., 0.], &[0., 1.]]);
        let z = Grid::filled(0.0, 2, 2);
        let out = task_cmp(&m, &m);
        assert!((out[0] - 1.0).abs() < 1e-5, "dice {}", out[0]);
        assert!((out[1] - 1.0).abs() < 1e-5, "jaccard {}", out[1]);
        assert!(out[2].abs() < 1e-7);
        let d = task_cmp(&m, &z);
        assert!(d[0] < 0.1, "disjoint dice {}", d[0]);
    }

    #[test]
    fn area_filter_drops_small_components() {
        let two = grid(&[
            &[1., 0., 0., 0.],
            &[0., 0., 1., 1.],
            &[0., 0., 1., 1.],
        ]);
        let out = area_filter(&two, 2.0, 100.0, true);
        assert_eq!(out.at(0, 0), 0.0, "singleton dropped");
        assert_eq!(out.at(1, 2), 1.0, "2x2 blob kept");
    }

    #[test]
    fn watershed_separates_two_deep_basins() {
        // two 3x3 blobs joined by a 1-px bridge: two depth maxima
        let mut mask = Grid::filled(0.0, 5, 9);
        for y in 1..4 {
            for x in 1..4 {
                mask.set(y, x, 1.0);
            }
        }
        for y in 1..4 {
            for x in 5..8 {
                mask.set(y, x, 1.0);
            }
        }
        mask.set(2, 4, 1.0); // bridge
        let depth = erosion_depth(&mask);
        let labels = watershed(&mask, &depth, true);
        let a = labels.at(2, 2);
        let b = labels.at(2, 6);
        assert!(a > 0.5 && b > 0.5, "both centers labeled: {a} {b}");
        assert_ne!(a, b, "touching nuclei split into separate labels");
    }

    /// Deterministic pseudo-random grid (splitmix-style) for equivalence
    /// sweeps.
    fn noise_grid(seed: u64, h: usize, w: usize, lo: f32, hi: f32) -> Grid {
        let mut s = seed;
        let data = (0..h * w)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let u = ((s >> 33) & 0xffff) as f32 / 65535.0;
                lo + u * (hi - lo)
            })
            .collect();
        Grid::new(data, h, w)
    }

    #[test]
    fn batched_chain_matches_scalar_lanes() {
        // Three lanes with distinct parameters — including mixed 4/8
        // connectivity — chained through every task. Each lane of the
        // batched output must equal the scalar kernel bit-for-bit.
        let (h, w) = (14, 11);
        let tile = [
            noise_grid(11, h, w, 0.0, 255.0),
            noise_grid(22, h, w, 0.0, 255.0),
            noise_grid(33, h, w, 0.0, 255.0),
        ];
        let lane_params: [Vec<Vec<f32>>; 8] = [
            /* norm */ vec![vec![], vec![], vec![]],
            /* t1 */
            vec![
                vec![220.0, 220.0, 220.0, 4.0, 4.0],
                vec![200.0, 210.0, 215.0, 3.0, 5.0],
                vec![235.0, 215.0, 205.0, 4.5, 3.5],
            ],
            /* t2 */ vec![vec![40.0, 8.0], vec![60.0, 4.0], vec![25.0, 8.0]],
            /* t3 */ vec![vec![8.0], vec![4.0], vec![8.0]],
            /* t4 */
            vec![vec![20.0, 10.0, 1200.0], vec![5.0, 2.0, 800.0], vec![50.0, 4.0, 1500.0]],
            /* t5 */ vec![vec![10.0], vec![2.0], vec![1.0]],
            /* t6 */ vec![vec![8.0], vec![4.0], vec![8.0]],
            /* t7 */ vec![vec![10.0, 1200.0], vec![2.0, 500.0], vec![4.0, 1000.0]],
        ];
        // per-lane scalar chain states
        let mut scalar: Vec<[Grid; 3]> =
            vec![tile.clone(), tile.clone(), tile.clone()];
        for (ti, name) in TASKS.iter().enumerate() {
            let params: Vec<&[f32]> =
                lane_params[ti].iter().map(|p| p.as_slice()).collect();
            let batched = run_task_batch(name, &scalar, &params).expect("batched task");
            let mut next: Vec<[Grid; 3]> = Vec::new();
            for (l, state) in scalar.iter().enumerate() {
                let out = run_task(name, &state[..], params[l]).expect("scalar task");
                let TaskOutput::Planes(planes) = out else {
                    panic!("chain task returned metrics")
                };
                for (bp, sp) in batched[l].iter().zip(planes.iter()) {
                    assert_eq!(bp, sp, "task {name}, lane {l}: batched output drifted");
                }
                next.push(planes);
            }
            scalar = next;
        }
    }

    #[test]
    fn run_task_batch_validates_inputs() {
        let g = Grid::filled(1.0, 3, 3);
        let st = [g.clone(), g.clone(), g.clone()];
        let p: &[f32] = &[0.0; 5];
        assert!(run_task_batch("cmp", &[st.clone()], &[p]).is_err(), "cmp is not batchable");
        assert!(run_task_batch("t1", &[st.clone()], &[p, p]).is_err(), "arity mismatch");
        assert!(run_task_batch("t1", &[], &[]).unwrap().is_empty());
        let bad = [g.clone(), g.clone(), Grid::filled(0.0, 2, 2)];
        assert!(run_task_batch("t1", &[st, bad], &[p, p]).is_err(), "shape mismatch");
    }

    #[test]
    fn run_task_validates_inputs() {
        let g = Grid::filled(1.0, 2, 2);
        assert!(run_task("t1", &[g.clone(), g.clone()], &[]).is_err());
        assert!(run_task("bogus", &[g.clone(), g.clone(), g.clone()], &[]).is_err());
        assert!(run_task("norm", &[g.clone(), g.clone(), g], &[0.0; 5]).is_ok());
    }
}
