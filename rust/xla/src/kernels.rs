//! Pure-Rust port of the nine workflow task kernels.
//!
//! This is a line-for-line port of `python/compile/model.py` (whose jnp
//! oracles live in `python/compile/kernels/ref.py`): stain normalization,
//! the seven fine-grain segmentation tasks `t1`..`t7`, and the `cmp`
//! mask-comparison task. The propagation operators (morphological
//! reconstruction, hole filling, connected components, watershed) are the
//! same IWPP fixpoint sweeps the Pallas kernels implement, iterated to
//! convergence on the CPU.
//!
//! Semantics must match the JAX model exactly where it matters for the
//! paper experiments: identical masks for identical inputs, monotone
//! responses to the Table-1 parameters, and deterministic output across
//! re-executions.
//!
//! NOTE: when changing any kernel's semantics, also bump the
//! `sha256_16` tags in `rust/artifacts/manifest.json` (currently
//! `native-stub-r1`) — the cross-study cache folds the artifact
//! fingerprint into its keys, and stale persistent entries are only
//! invalidated when that fingerprint moves.

/// Maximum sweeps for any fixpoint loop (safety net; convergence exits
/// earlier — propagation distance is bounded by the tile diagonal).
const MAX_SWEEPS: usize = 4096;

/// Erosion depth levels tracked for watershed seeding.
pub const DEPTH_LEVELS: usize = 16;

/// Normalization targets (model.py `_NORM_MEAN` / `_NORM_STD`).
const NORM_MEAN: f32 = 210.0;
const NORM_STD: f32 = 40.0;

/// h-maxima suppression height for watershed seeding.
const SEED_H: f32 = 2.0;

/// Fixed h-dome height for candidate extraction (t2).
const DOME_H: f32 = 100.0;

/// A row-major 2-D f32 image plane.
#[derive(Clone, Debug, PartialEq)]
pub struct Grid {
    pub data: Vec<f32>,
    pub h: usize,
    pub w: usize,
}

impl Grid {
    pub fn new(data: Vec<f32>, h: usize, w: usize) -> Self {
        assert_eq!(data.len(), h * w, "grid data length mismatch");
        Self { data, h, w }
    }

    pub fn filled(v: f32, h: usize, w: usize) -> Self {
        Self { data: vec![v; h * w], h, w }
    }

    #[inline]
    fn at(&self, y: usize, x: usize) -> f32 {
        self.data[y * self.w + x]
    }

    #[inline]
    fn set(&mut self, y: usize, x: usize, v: f32) {
        self.data[y * self.w + x] = v;
    }

    fn map(&self, f: impl Fn(f32) -> f32) -> Grid {
        Grid { data: self.data.iter().map(|&v| f(v)).collect(), h: self.h, w: self.w }
    }

    fn zip(&self, other: &Grid, f: impl Fn(f32, f32) -> f32) -> Grid {
        debug_assert_eq!((self.h, self.w), (other.h, other.w));
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect();
        Grid { data, h: self.h, w: self.w }
    }
}

/// What a task produces: three chain planes, or the cmp metrics triple.
pub enum TaskOutput {
    Planes([Grid; 3]),
    Metrics([f32; 3]),
}

// ---------------------------------------------------------------------------
// neighborhood sweeps (the L1 kernels)
// ---------------------------------------------------------------------------

/// Neighborhood extremum including the center pixel; out-of-bounds
/// neighbors are skipped (equivalent to the oracles' ±inf padding).
fn nbr_ext(x: &Grid, conn8: bool, ext: impl Fn(f32, f32) -> f32) -> Grid {
    let (h, w) = (x.h, x.w);
    let mut out = Grid::filled(0.0, h, w);
    for y in 0..h {
        for c in 0..w {
            let mut v = x.at(y, c);
            if y > 0 {
                v = ext(v, x.at(y - 1, c));
            }
            if y + 1 < h {
                v = ext(v, x.at(y + 1, c));
            }
            if c > 0 {
                v = ext(v, x.at(y, c - 1));
            }
            if c + 1 < w {
                v = ext(v, x.at(y, c + 1));
            }
            if conn8 {
                if y > 0 && c > 0 {
                    v = ext(v, x.at(y - 1, c - 1));
                }
                if y > 0 && c + 1 < w {
                    v = ext(v, x.at(y - 1, c + 1));
                }
                if y + 1 < h && c > 0 {
                    v = ext(v, x.at(y + 1, c - 1));
                }
                if y + 1 < h && c + 1 < w {
                    v = ext(v, x.at(y + 1, c + 1));
                }
            }
            out.set(y, c, v);
        }
    }
    out
}

fn nbr_max(x: &Grid, conn8: bool) -> Grid {
    nbr_ext(x, conn8, f32::max)
}

fn nbr_min(x: &Grid, conn8: bool) -> Grid {
    nbr_ext(x, conn8, f32::min)
}

/// One reconstruction-by-dilation sweep: min(dilate(marker), mask).
fn recon_sweep(marker: &Grid, mask: &Grid, conn8: bool) -> Grid {
    nbr_max(marker, conn8).zip(mask, f32::min)
}

/// One label-growing sweep: unlabeled active pixels take the max
/// neighboring label.
fn label_sweep(labels: &Grid, active: &Grid, conn8: bool) -> Grid {
    let nbr = nbr_max(labels, conn8);
    let mut out = labels.clone();
    for i in 0..out.data.len() {
        if out.data[i] == 0.0 && active.data[i] > 0.5 {
            out.data[i] = nbr.data[i];
        }
    }
    out
}

/// Iterate a monotone sweep until the image stops changing.
fn fixpoint(init: Grid, sweep: impl Fn(&Grid) -> Grid) -> Grid {
    let mut cur = init;
    for _ in 0..MAX_SWEEPS {
        let nxt = sweep(&cur);
        if nxt.data == cur.data {
            return nxt;
        }
        cur = nxt;
    }
    cur
}

// ---------------------------------------------------------------------------
// propagation operators
// ---------------------------------------------------------------------------

/// Greyscale morphological reconstruction by dilation (IWPP fixpoint).
fn morph_reconstruct(marker: &Grid, mask: &Grid, conn8: bool) -> Grid {
    let init = marker.zip(mask, f32::min);
    fixpoint(init, |m| recon_sweep(m, mask, conn8))
}

/// Fill holes: background not reachable from the border becomes object.
fn fill_holes(binary: &Grid, conn8: bool) -> Grid {
    let (h, w) = (binary.h, binary.w);
    let comp = binary.map(|v| 1.0 - v);
    let mut marker = Grid::filled(0.0, h, w);
    for y in 0..h {
        for c in 0..w {
            if y == 0 || y == h - 1 || c == 0 || c == w - 1 {
                marker.set(y, c, comp.at(y, c));
            }
        }
    }
    let outside = fixpoint(marker, |m| recon_sweep(m, &comp, conn8));
    let mut out = Grid::filled(0.0, h, w);
    for i in 0..out.data.len() {
        let keep = if outside.data[i] > 0.5 { 0.0 } else { 1.0 };
        out.data[i] = keep * binary.data[i].max(comp.data[i]);
    }
    out
}

/// Label connected components with the min linear index + 1 (0 = bg),
/// via min-propagation under a per-pixel ceiling (negated-label trick:
/// shares the reconstruction sweep kernel).
fn connected_components(mask: &Grid, conn8: bool) -> Grid {
    let (h, w) = (mask.h, mask.w);
    let big = (h * w) as f32 + 2.0;
    let mut neg = Grid::filled(0.0, h, w);
    let mut ceil = Grid::filled(0.0, h, w);
    for i in 0..neg.data.len() {
        if mask.data[i] > 0.5 {
            neg.data[i] = -(i as f32 + 1.0);
            ceil.data[i] = 0.0;
        } else {
            neg.data[i] = -big;
            ceil.data[i] = -big;
        }
    }
    let out = fixpoint(neg, |m| recon_sweep(m, &ceil, conn8));
    let mut labels = Grid::filled(0.0, h, w);
    for i in 0..labels.data.len() {
        if mask.data[i] > 0.5 {
            labels.data[i] = -out.data[i];
        }
    }
    labels
}

/// Per-pixel size of the pixel's component (0 on background).
fn component_sizes(labels: &Grid) -> Grid {
    let n = labels.h * labels.w + 2;
    let mut counts = vec![0.0f32; n];
    for &l in &labels.data {
        counts[(l.max(0.0) as usize).min(n - 1)] += 1.0;
    }
    let mut out = Grid::filled(0.0, labels.h, labels.w);
    for i in 0..out.data.len() {
        let l = labels.data[i];
        if l > 0.5 {
            out.data[i] = counts[(l as usize).min(n - 1)];
        }
    }
    out
}

/// Per-pixel max of `values` over the pixel's component (0 on bg).
fn component_max(labels: &Grid, values: &Grid) -> Grid {
    let n = labels.h * labels.w + 2;
    let mut maxes = vec![f32::NEG_INFINITY; n];
    for i in 0..labels.data.len() {
        let slot = (labels.data[i].max(0.0) as usize).min(n - 1);
        maxes[slot] = maxes[slot].max(values.data[i]);
    }
    let mut out = Grid::filled(0.0, labels.h, labels.w);
    for i in 0..out.data.len() {
        let l = labels.data[i];
        if l > 0.5 {
            out.data[i] = maxes[(l as usize).min(n - 1)];
        }
    }
    out
}

/// Drop connected components with size outside [min_size, max_size].
fn area_filter(mask: &Grid, min_size: f32, max_size: f32, conn8: bool) -> Grid {
    let labels = connected_components(mask, conn8);
    let sizes = component_sizes(&labels);
    let mut out = Grid::filled(0.0, mask.h, mask.w);
    for i in 0..out.data.len() {
        if (min_size..=max_size).contains(&sizes.data[i]) {
            out.data[i] = mask.data[i];
        }
    }
    out
}

/// Number of 8-conn erosions each pixel survives, + 1 on the mask.
fn erosion_depth(mask: &Grid) -> Grid {
    let mut cur = mask.clone();
    let mut depth = mask.clone();
    for _ in 0..DEPTH_LEVELS - 1 {
        cur = nbr_min(&cur, true);
        for i in 0..depth.data.len() {
            depth.data[i] += cur.data[i];
        }
    }
    depth
}

/// Seeded watershed by level-ordered label growing (dense IWPP form).
/// Seeds are the h-maxima of `depth` (h = SEED_H); low-relief components
/// seed from their peak plateau. See model.py `watershed` for the full
/// rationale.
fn watershed(mask: &Grid, depth: &Grid, conn8: bool) -> Grid {
    let (h, w) = (mask.h, mask.w);
    let marker = depth.map(|v| (v - SEED_H).max(0.0));
    let hrecon = morph_reconstruct(&marker, depth, true);
    let comp = connected_components(mask, true);
    let peak = component_max(&comp, depth);

    let mut seed_mask = Grid::filled(0.0, h, w);
    for i in 0..seed_mask.data.len() {
        let inside = mask.data[i] > 0.5;
        let hseed = depth.data[i] - hrecon.data[i] >= SEED_H && inside;
        let lowseed = peak.data[i] < SEED_H && depth.data[i] >= peak.data[i] && inside;
        if hseed || lowseed {
            seed_mask.data[i] = 1.0;
        }
    }
    let mut labels = connected_components(&seed_mask, true);

    for i in 0..DEPTH_LEVELS {
        let level = (DEPTH_LEVELS - i) as f32;
        let mut active = Grid::filled(0.0, h, w);
        for j in 0..active.data.len() {
            if depth.data[j] >= level && mask.data[j] > 0.5 {
                active.data[j] = 1.0;
            }
        }
        labels = fixpoint(labels, |l| label_sweep(l, &active, conn8));
    }
    for i in 0..labels.data.len() {
        if mask.data[i] <= 0.5 {
            labels.data[i] = 0.0;
        }
    }
    labels
}

// ---------------------------------------------------------------------------
// the workflow tasks
// ---------------------------------------------------------------------------

fn normalize_channel(x: &Grid) -> Grid {
    let n = x.data.len() as f64;
    let mu = x.data.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = x.data.iter().map(|&v| (v as f64 - mu) * (v as f64 - mu)).sum::<f64>() / n;
    let sd = var.sqrt() as f32 + 1e-6;
    let mu = mu as f32;
    x.map(|v| ((v - mu) / sd * NORM_STD + NORM_MEAN).clamp(0.0, 255.0))
}

fn task_norm(a: &Grid, b: &Grid, c: &Grid) -> [Grid; 3] {
    [normalize_channel(a), normalize_channel(b), normalize_channel(c)]
}

fn task_t1(r: &Grid, g: &Grid, bl: &Grid, p: &[f32]) -> [Grid; 3] {
    let (bb, gg, rr, t1, t2) = (par(p, 0), par(p, 1), par(p, 2), par(p, 3), par(p, 4));
    let (h, w) = (r.h, r.w);
    let mut grey = Grid::filled(0.0, h, w);
    let mut fg = Grid::filled(0.0, h, w);
    for i in 0..grey.data.len() {
        let (rv, gv, bv) = (r.data[i], g.data[i], bl.data[i]);
        let background = rv > bb && gv > gg && bv > rr;
        let rbc = (rv + 1.0) / (gv + 1.0) > t1 && (rv + 1.0) / (bv + 1.0) > t2;
        grey.data[i] = 255.0 - (0.299 * rv + 0.587 * gv + 0.114 * bv);
        fg.data[i] = if background || rbc { 0.0 } else { 1.0 };
    }
    let zeros = Grid::filled(0.0, h, w);
    [grey, fg, zeros]
}

fn task_t2(grey: &Grid, fg: &Grid, p: &[f32]) -> [Grid; 3] {
    let (g1, rc) = (par(p, 0), par(p, 1));
    let marker = grey.zip(fg, |gv, fv| (gv - DOME_H).max(0.0) * fv);
    let recon = morph_reconstruct(&marker, grey, rc >= 8.0);
    let domes = grey.zip(&recon, |gv, rv| gv - rv).zip(fg, |d, fv| d * fv);
    let cand = domes.map(|d| if d >= g1 { 1.0 } else { 0.0 });
    [grey.clone(), cand, domes]
}

fn task_t3(grey: &Grid, cand: &Grid, domes: &Grid, p: &[f32]) -> [Grid; 3] {
    let fh = par(p, 0);
    [grey.clone(), fill_holes(cand, fh >= 8.0), domes.clone()]
}

fn task_t4(grey: &Grid, filled: &Grid, domes: &Grid, p: &[f32]) -> [Grid; 3] {
    let (g2, min_s, max_s) = (par(p, 0), par(p, 1), par(p, 2));
    let labels = connected_components(filled, true);
    let sizes = component_sizes(&labels);
    let peak = component_max(&labels, domes);
    let mut kept = Grid::filled(0.0, filled.h, filled.w);
    for i in 0..kept.data.len() {
        let keep = (min_s..=max_s).contains(&sizes.data[i]) && peak.data[i] >= g2;
        if keep {
            kept.data[i] = filled.data[i];
        }
    }
    [grey.clone(), kept, domes.clone()]
}

fn task_t5(grey: &Grid, kept: &Grid, p: &[f32]) -> [Grid; 3] {
    let min_spl = par(p, 0);
    let mask = area_filter(kept, min_spl, 1e9, true);
    let depth = erosion_depth(&mask);
    [grey.clone(), mask, depth]
}

fn task_t6(grey: &Grid, mask: &Grid, depth: &Grid, p: &[f32]) -> [Grid; 3] {
    let wconn = par(p, 0);
    let labels = watershed(mask, depth, wconn >= 8.0);
    let seg = labels.map(|l| if l > 0.5 { 1.0 } else { 0.0 });
    [grey.clone(), seg, labels]
}

fn task_t7(grey: &Grid, seg: &Grid, labels: &Grid, p: &[f32]) -> [Grid; 3] {
    let (min_ss, max_ss) = (par(p, 0), par(p, 1));
    let sizes = component_sizes(labels);
    let mut fin = Grid::filled(0.0, seg.h, seg.w);
    let mut lab = Grid::filled(0.0, seg.h, seg.w);
    for i in 0..fin.data.len() {
        let keep = (min_ss..=max_ss).contains(&sizes.data[i]) && seg.data[i] > 0.5;
        if keep {
            fin.data[i] = 1.0;
            lab.data[i] = labels.data[i];
        }
    }
    [grey.clone(), fin, lab]
}

fn task_cmp(b: &Grid, reference: &Grid) -> [f32; 3] {
    let mut inter = 0.0f64;
    let mut sm = 0.0f64;
    let mut sr = 0.0f64;
    let mut diff = 0.0f64;
    for i in 0..b.data.len() {
        let m = if b.data[i] > 0.5 { 1.0f64 } else { 0.0 };
        let r = if reference.data[i] > 0.5 { 1.0f64 } else { 0.0 };
        inter += m * r;
        sm += m;
        sr += r;
        diff += (m - r).abs();
    }
    let union = sm + sr - inter;
    let dice = (2.0 * inter + 1e-6) / (sm + sr + 1e-6);
    let jacc = (inter + 1e-6) / (union + 1e-6);
    let mean_diff = diff / b.data.len().max(1) as f64;
    [dice as f32, jacc as f32, mean_diff as f32]
}

#[inline]
fn par(p: &[f32], i: usize) -> f32 {
    p.get(i).copied().unwrap_or(0.0)
}

/// Execute one workflow task. Chain tasks take 3 planes, `cmp` takes 4
/// (state + reference mask); `params` is the padded parameter vector.
pub fn run_task(name: &str, planes: &[Grid], params: &[f32]) -> Result<TaskOutput, String> {
    let need = if name == "cmp" { 4 } else { 3 };
    if planes.len() != need {
        return Err(format!("task `{name}` needs {need} planes, got {}", planes.len()));
    }
    let (a, b, c) = (&planes[0], &planes[1], &planes[2]);
    let out = match name {
        "norm" => TaskOutput::Planes(task_norm(a, b, c)),
        "t1" => TaskOutput::Planes(task_t1(a, b, c, params)),
        "t2" => TaskOutput::Planes(task_t2(a, b, params)),
        "t3" => TaskOutput::Planes(task_t3(a, b, c, params)),
        "t4" => TaskOutput::Planes(task_t4(a, b, c, params)),
        "t5" => TaskOutput::Planes(task_t5(a, b, params)),
        "t6" => TaskOutput::Planes(task_t6(a, b, c, params)),
        "t7" => TaskOutput::Planes(task_t7(a, b, c, params)),
        "cmp" => TaskOutput::Metrics(task_cmp(b, &planes[3])),
        other => return Err(format!("unknown task `{other}`")),
    };
    Ok(out)
}

/// The chain task names in execution order.
pub const TASKS: [&str; 8] = ["norm", "t1", "t2", "t3", "t4", "t5", "t6", "t7"];

/// All task names this backend can execute (chain tasks + `cmp`).
pub fn known_task(name: &str) -> bool {
    name == "cmp" || TASKS.contains(&name)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid(rows: &[&[f32]]) -> Grid {
        let h = rows.len();
        let w = rows[0].len();
        Grid::new(rows.iter().flat_map(|r| r.iter().copied()).collect(), h, w)
    }

    #[test]
    fn fill_holes_closes_enclosed_background() {
        // 5x5 ring of ones with a hole in the middle
        let ring = grid(&[
            &[0., 0., 0., 0., 0.],
            &[0., 1., 1., 1., 0.],
            &[0., 1., 0., 1., 0.],
            &[0., 1., 1., 1., 0.],
            &[0., 0., 0., 0., 0.],
        ]);
        let filled = fill_holes(&ring, false);
        assert_eq!(filled.at(2, 2), 1.0, "hole must fill");
        assert_eq!(filled.at(0, 0), 0.0, "outside stays background");
        assert_eq!(filled.at(1, 1), 1.0, "object survives");
    }

    #[test]
    fn connected_components_labels_blobs_distinctly() {
        let two = grid(&[
            &[1., 1., 0., 0., 0.],
            &[1., 1., 0., 0., 0.],
            &[0., 0., 0., 1., 1.],
            &[0., 0., 0., 1., 1.],
        ]);
        let labels = connected_components(&two, true);
        let a = labels.at(0, 0);
        let b = labels.at(3, 4);
        assert!(a > 0.5 && b > 0.5);
        assert_ne!(a, b, "separate blobs get separate labels");
        assert_eq!(labels.at(0, 1), a, "blob is label-uniform");
        assert_eq!(labels.at(2, 0), 0.0, "background is 0");
        let sizes = component_sizes(&labels);
        assert_eq!(sizes.at(0, 0), 4.0);
        assert_eq!(sizes.at(2, 3), 4.0);
        assert_eq!(sizes.at(2, 0), 0.0);
    }

    #[test]
    fn reconstruction_never_exceeds_mask() {
        let mask = grid(&[&[5., 5., 1.], &[5., 9., 1.], &[1., 1., 1.]]);
        let marker = grid(&[&[0., 0., 0.], &[0., 7., 0.], &[0., 0., 0.]]);
        let rec = morph_reconstruct(&marker, &mask, true);
        for i in 0..rec.data.len() {
            assert!(rec.data[i] <= mask.data[i] + 1e-6);
        }
        // the 7-marker dilates through the 5-plateau but is capped by it
        assert_eq!(rec.at(0, 0), 5.0);
        assert_eq!(rec.at(1, 1), 7.0);
        assert_eq!(rec.at(2, 2), 1.0);
    }

    #[test]
    fn self_compare_is_perfect() {
        let m = grid(&[&[1., 0.], &[0., 1.]]);
        let z = Grid::filled(0.0, 2, 2);
        let out = task_cmp(&m, &m);
        assert!((out[0] - 1.0).abs() < 1e-5, "dice {}", out[0]);
        assert!((out[1] - 1.0).abs() < 1e-5, "jaccard {}", out[1]);
        assert!(out[2].abs() < 1e-7);
        let d = task_cmp(&m, &z);
        assert!(d[0] < 0.1, "disjoint dice {}", d[0]);
    }

    #[test]
    fn area_filter_drops_small_components() {
        let two = grid(&[
            &[1., 0., 0., 0.],
            &[0., 0., 1., 1.],
            &[0., 0., 1., 1.],
        ]);
        let out = area_filter(&two, 2.0, 100.0, true);
        assert_eq!(out.at(0, 0), 0.0, "singleton dropped");
        assert_eq!(out.at(1, 2), 1.0, "2x2 blob kept");
    }

    #[test]
    fn watershed_separates_two_deep_basins() {
        // two 3x3 blobs joined by a 1-px bridge: two depth maxima
        let mut mask = Grid::filled(0.0, 5, 9);
        for y in 1..4 {
            for x in 1..4 {
                mask.set(y, x, 1.0);
            }
        }
        for y in 1..4 {
            for x in 5..8 {
                mask.set(y, x, 1.0);
            }
        }
        mask.set(2, 4, 1.0); // bridge
        let depth = erosion_depth(&mask);
        let labels = watershed(&mask, &depth, true);
        let a = labels.at(2, 2);
        let b = labels.at(2, 6);
        assert!(a > 0.5 && b > 0.5, "both centers labeled: {a} {b}");
        assert_ne!(a, b, "touching nuclei split into separate labels");
    }

    #[test]
    fn run_task_validates_inputs() {
        let g = Grid::filled(1.0, 2, 2);
        assert!(run_task("t1", &[g.clone(), g.clone()], &[]).is_err());
        assert!(run_task("bogus", &[g.clone(), g.clone(), g.clone()], &[]).is_err());
        assert!(run_task("norm", &[g.clone(), g.clone(), g], &[0.0; 5]).is_ok());
    }
}
