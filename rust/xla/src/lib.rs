//! Native CPU fallback for the `xla` PJRT binding.
//!
//! This crate exposes the exact API surface of the published `xla` crate
//! (v0.1.6) that `rtf-reuse` uses — `PjRtClient`, `PjRtLoadedExecutable`,
//! `Literal`, `HloModuleProto`, `XlaComputation` — but executes the nine
//! workflow tasks with a pure-Rust interpreter ([`kernels`]) instead of
//! libxla. The build environment carries no XLA shared libraries, and the
//! AOT artifacts' HLO text is only used to identify *which* task an
//! artifact encodes (module name, or an explicit `rtf-native-task:`
//! marker in stub artifacts).
//!
//! **Substitution contract.** On hosts with the real toolchain, point the
//! `xla` dependency of `rtf-reuse` back at the published crate and
//! regenerate real artifacts with `python -m compile.aot`; no call site
//! changes. The fallback preserves the properties the experiments rely
//! on: deterministic outputs, identical results for identical inputs,
//! and per-task execution cost that scales with tile area.

pub mod kernels;

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

use kernels::{Grid, TaskOutput};

/// Errors surfaced by the backend (the published crate's `xla::Error`
/// analog; a single message-carrying variant suffices here).
#[derive(Clone, Debug)]
pub enum Error {
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Msg(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {}

/// Crate-local result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn err(msg: impl Into<String>) -> Error {
    Error::Msg(msg.into())
}

// ---------------------------------------------------------------------------
// literals
// ---------------------------------------------------------------------------

/// Element types a [`Literal`] can yield through [`Literal::to_vec`].
/// Only `f32` is needed by the workflow artifacts.
pub trait NativeType: Sized {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
}

#[derive(Clone, Debug, PartialEq)]
enum Repr {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    Tuple(Vec<Literal>),
}

/// A host-resident array (or tuple of arrays) — the unit of transfer
/// between the coordinator and the backend.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    repr: Repr,
}

impl Literal {
    /// A rank-1 f32 literal.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { repr: Repr::F32 { data: data.to_vec(), dims: vec![data.len()] } }
    }

    /// A tuple literal.
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { repr: Repr::Tuple(elements) }
    }

    /// Reinterpret the element buffer under new dimensions.
    pub fn reshape(self, dims: &[i64]) -> Result<Literal> {
        match self.repr {
            Repr::F32 { data, .. } => {
                let want: usize = dims.iter().map(|&d| d.max(0) as usize).product();
                if want != data.len() {
                    return Err(err(format!(
                        "cannot reshape {} elements to {dims:?}",
                        data.len()
                    )));
                }
                let dims = dims.iter().map(|&d| d.max(0) as usize).collect();
                Ok(Literal { repr: Repr::F32 { data, dims } })
            }
            Repr::Tuple(_) => Err(err("cannot reshape a tuple literal")),
        }
    }

    /// Copy the elements out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match &self.repr {
            Repr::F32 { data, .. } => Ok(data.iter().map(|&v| T::from_f32(v)).collect()),
            Repr::Tuple(_) => Err(err("to_vec on a tuple literal")),
        }
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.repr {
            Repr::Tuple(elements) => Ok(elements),
            Repr::F32 { .. } => Err(err("to_tuple on an array literal")),
        }
    }

    /// Unwrap a 1-tuple.
    pub fn to_tuple1(self) -> Result<Literal> {
        let mut elements = self.to_tuple()?;
        if elements.len() != 1 {
            return Err(err(format!("to_tuple1 on a {}-tuple", elements.len())));
        }
        Ok(elements.pop().expect("len checked"))
    }

    /// Array dimensions (empty for tuples).
    pub fn dims(&self) -> &[usize] {
        match &self.repr {
            Repr::F32 { dims, .. } => dims,
            Repr::Tuple(_) => &[],
        }
    }

    fn as_grid(&self) -> Result<Grid> {
        match &self.repr {
            Repr::F32 { data, dims } if dims.len() == 2 => {
                Ok(Grid::new(data.clone(), dims[0], dims[1]))
            }
            _ => Err(err("expected a rank-2 f32 literal")),
        }
    }

    fn from_grid(g: Grid) -> Literal {
        Literal { repr: Repr::F32 { dims: vec![g.h, g.w], data: g.data } }
    }
}

// ---------------------------------------------------------------------------
// HLO artifacts
// ---------------------------------------------------------------------------

/// A parsed HLO module. The native backend only needs the task identity,
/// recovered from an `rtf-native-task:` marker (stub artifacts) or the
/// `HloModule` name (real jax-lowered artifacts, e.g. `jit_t4`).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    task: String,
}

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| err(format!("cannot read {}: {e}", path.display())))?;
        let task = task_name_from_hlo(&text).ok_or_else(|| {
            err(format!("no task identity found in HLO text {}", path.display()))
        })?;
        Ok(Self { task })
    }

    /// The task this module encodes.
    pub fn name(&self) -> &str {
        &self.task
    }
}

fn task_name_from_hlo(text: &str) -> Option<String> {
    for line in text.lines() {
        if let Some(rest) = line.split("rtf-native-task:").nth(1) {
            let name: String =
                rest.trim().chars().take_while(|c| c.is_alphanumeric()).collect();
            if !name.is_empty() {
                return Some(name);
            }
        }
    }
    for line in text.lines() {
        if let Some(rest) = line.trim_start().strip_prefix("HloModule ") {
            let token = rest.split([',', ' ']).next().unwrap_or("");
            let mut name = token;
            for prefix in ["jit_", "xla_computation_", "task_"] {
                name = name.strip_prefix(prefix).unwrap_or(name);
            }
            // jax may append a uniquifier, e.g. `t4.1`
            let name = name.split('.').next().unwrap_or(name);
            if !name.is_empty() {
                return Some(name.to_string());
            }
        }
    }
    None
}

/// A computation ready for compilation (wraps the parsed module).
#[derive(Clone, Debug)]
pub struct XlaComputation {
    task: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { task: proto.task.clone() }
    }
}

// ---------------------------------------------------------------------------
// client / executable / buffers
// ---------------------------------------------------------------------------

/// The (stateless) CPU client.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    /// "Compile" a computation: validate the task is known to the native
    /// interpreter and return an executable bound to it.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        if !kernels::known_task(&comp.task) {
            return Err(err(format!(
                "native backend cannot execute task `{}`",
                comp.task
            )));
        }
        Ok(PjRtLoadedExecutable { task: comp.task.clone() })
    }
}

/// A device-resident output buffer (host-resident here).
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Transfer the buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// A compiled executable for one workflow task.
#[derive(Clone, Debug)]
pub struct PjRtLoadedExecutable {
    task: String,
}

impl PjRtLoadedExecutable {
    /// Execute the task. Inputs are the task's image planes (rank-2
    /// literals, in order) followed by the padded parameter vector
    /// (rank-1). Returns one result buffer holding the output tuple, in
    /// the `Vec<Vec<..>>` (replica × output) shape of the PJRT API.
    pub fn execute<L: Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let mut planes: Vec<Grid> = Vec::new();
        let mut params: Vec<f32> = Vec::new();
        for a in args {
            let lit = a.borrow();
            match lit.dims().len() {
                2 => planes.push(lit.as_grid()?),
                1 => params = lit.to_vec::<f32>()?,
                r => return Err(err(format!("unsupported input rank {r}"))),
            }
        }
        if let Some(first) = planes.first() {
            let (h, w) = (first.h, first.w);
            if planes.iter().any(|p| p.h != h || p.w != w) {
                return Err(err("input planes disagree on shape"));
            }
        }
        let out = kernels::run_task(&self.task, &planes, &params).map_err(Error::Msg)?;
        let tuple = match out {
            TaskOutput::Planes([a, b, c]) => Literal::tuple(vec![
                Literal::from_grid(a),
                Literal::from_grid(b),
                Literal::from_grid(c),
            ]),
            TaskOutput::Metrics(m) => Literal::tuple(vec![Literal::vec1(&m)]),
        };
        Ok(vec![vec![PjRtBuffer { literal: tuple }]])
    }

    /// Execute a 3-plane chain task over a batch of B states × B
    /// parameter vectors in one call: `states[i]` are lane i's input
    /// planes, `params[i]` its parameter vector. The native interpreter
    /// vectorizes the per-pixel inner loops across the batch
    /// ([`kernels::run_task_batch`]); every lane's output is
    /// bit-identical to a [`PjRtLoadedExecutable::execute`] call on the
    /// same inputs.
    ///
    /// This is an *extension* over the published `xla` crate's API
    /// surface: when substituting the real binding, provide a shim that
    /// loops over `execute` (results are identical, only the batching
    /// speedup is lost).
    pub fn execute_batch(
        &self,
        states: &[&[Literal; 3]],
        params: &[&[f32]],
    ) -> Result<Vec<[Literal; 3]>> {
        let mut grids: Vec<[Grid; 3]> = Vec::with_capacity(states.len());
        for s in states {
            grids.push([s[0].as_grid()?, s[1].as_grid()?, s[2].as_grid()?]);
        }
        let outs = kernels::run_task_batch(&self.task, &grids, params).map_err(Error::Msg)?;
        Ok(outs
            .into_iter()
            .map(|[a, b, c]| {
                [Literal::from_grid(a), Literal::from_grid(b), Literal::from_grid(c)]
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plane_lit(v: f32, h: usize, w: usize) -> Literal {
        Literal::vec1(&vec![v; h * w]).reshape(&[h as i64, w as i64]).unwrap()
    }

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(Literal::vec1(&[1.0]).reshape(&[3, 3]).is_err());
    }

    #[test]
    fn tuple_accessors() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.0]), Literal::vec1(&[2.0])]);
        let parts = t.clone().to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(t.to_tuple1().is_err());
        let one = Literal::tuple(vec![Literal::vec1(&[7.0])]);
        assert_eq!(one.to_tuple1().unwrap().to_vec::<f32>().unwrap(), vec![7.0]);
    }

    #[test]
    fn hlo_task_identity_from_marker_and_module_name() {
        let dir = std::env::temp_dir().join(format!("xla-native-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let stub = dir.join("stub.hlo.txt");
        std::fs::write(&stub, "HloModule jit_t4\n// rtf-native-task: t4\n").unwrap();
        assert_eq!(HloModuleProto::from_text_file(&stub).unwrap().name(), "t4");
        let real = dir.join("real.hlo.txt");
        std::fs::write(&real, "HloModule jit_norm.2, entry_computation_layout=...\n").unwrap();
        assert_eq!(HloModuleProto::from_text_file(&real).unwrap().name(), "norm");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compile_rejects_unknown_tasks() {
        let client = PjRtClient::cpu().unwrap();
        let good = XlaComputation { task: "t3".into() };
        assert!(client.compile(&good).is_ok());
        let bad = XlaComputation { task: "resnet".into() };
        assert!(client.compile(&bad).is_err());
    }

    #[test]
    fn execute_norm_end_to_end() {
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&XlaComputation { task: "norm".into() }).unwrap();
        let inputs = vec![
            plane_lit(100.0, 4, 4),
            plane_lit(150.0, 4, 4),
            plane_lit(200.0, 4, 4),
            Literal::vec1(&[0.0; 5]),
        ];
        let out = exe.execute::<Literal>(&inputs).unwrap()[0][0].to_literal_sync().unwrap();
        let parts = out.to_tuple().unwrap();
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].dims(), &[4, 4]);
        // constant channel normalizes to the target mean
        let v = parts[0].to_vec::<f32>().unwrap();
        assert!(v.iter().all(|&x| (x - 210.0).abs() < 1e-3), "{v:?}");
    }

    #[test]
    fn execute_batch_matches_per_lane_execute() {
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&XlaComputation { task: "t1".into() }).unwrap();
        let state: [Literal; 3] =
            [plane_lit(100.0, 4, 4), plane_lit(150.0, 4, 4), plane_lit(200.0, 4, 4)];
        let p0: &[f32] = &[220.0, 220.0, 220.0, 4.0, 4.0];
        let p1: &[f32] = &[90.0, 120.0, 150.0, 1.0, 1.0];
        let batch = exe.execute_batch(&[&state, &state], &[p0, p1]).unwrap();
        assert_eq!(batch.len(), 2);
        for (lane, p) in [p0, p1].iter().enumerate() {
            let inputs =
                vec![state[0].clone(), state[1].clone(), state[2].clone(), Literal::vec1(p)];
            let out =
                exe.execute::<Literal>(&inputs).unwrap()[0][0].to_literal_sync().unwrap();
            let parts = out.to_tuple().unwrap();
            for (b, s) in batch[lane].iter().zip(&parts) {
                assert_eq!(
                    b.to_vec::<f32>().unwrap(),
                    s.to_vec::<f32>().unwrap(),
                    "lane {lane} drifted"
                );
            }
        }
        // cmp is not batchable
        let cmp = client.compile(&XlaComputation { task: "cmp".into() }).unwrap();
        assert!(cmp.execute_batch(&[&state], &[p0]).is_err());
    }

    #[test]
    fn execute_cmp_yields_metrics_tuple() {
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&XlaComputation { task: "cmp".into() }).unwrap();
        let mask = plane_lit(1.0, 3, 3);
        let inputs = vec![
            plane_lit(0.0, 3, 3),
            mask.clone(),
            plane_lit(0.0, 3, 3),
            mask,
            Literal::vec1(&[0.0; 5]),
        ];
        let out = exe.execute::<Literal>(&inputs).unwrap()[0][0].to_literal_sync().unwrap();
        let m = out.to_tuple1().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(m.len(), 3);
        assert!((m[0] - 1.0).abs() < 1e-5, "self dice {}", m[0]);
    }
}
